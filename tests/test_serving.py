"""Serving-layer integration: engines, schedulers, server, cloud, formats."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.add import (
    Deployment,
    ModelFormat,
    RequestProcessing,
    ServingInfrastructure,
)
from repro.core.engines import CompiledEngine, EagerEngine
from repro.models import init_params
from repro.serving import formats
from repro.serving.cloud import CloudService
from repro.serving.container import generate_artifact, overhead
from repro.serving.request import Request, synth_workload
from repro.serving.scheduler import (
    ContinuousBatchScheduler,
    DynamicBatchScheduler,
    RealTimeScheduler,
)
from repro.serving.server import ModelPackage, ServingServer

ARCH = "yi-9b-smoke"


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engines_agree(setup):
    """SI1 (eager) and SI2 (compiled) produce identical greedy tokens."""
    cfg, params = setup
    tokens = np.random.RandomState(0).randint(0, cfg.vocab_size,
                                              (2, 8)).astype(np.int32)
    e1 = EagerEngine(cfg, params, max_seq=32)
    e2 = CompiledEngine(cfg, params, max_seq=32)
    r1 = e1.generate(tokens, 4)
    r2 = e2.generate(tokens, 4)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_compiled_warmup_amortizes(setup):
    cfg, params = setup
    e = CompiledEngine(cfg, params, max_seq=32)
    compile_s = e.warmup(1, 8)
    tokens = np.zeros((1, 8), np.int32)
    r = e.generate(tokens, 4)
    assert compile_s > r.prefill_s + r.decode_s  # runtime-engine build >> run


@pytest.mark.parametrize("sched_cls", [RealTimeScheduler,
                                       DynamicBatchScheduler,
                                       ContinuousBatchScheduler])
def test_schedulers_complete_all(setup, sched_cls):
    cfg, params = setup
    engine = CompiledEngine(cfg, params, max_seq=64)
    wl = synth_workload(5, 8, 3, cfg.vocab_size, rate_per_s=100, seed=1)
    if sched_cls is RealTimeScheduler:
        sched = sched_cls(engine)
    elif sched_cls is DynamicBatchScheduler:
        sched = sched_cls(engine, max_batch=4, timeout_ms=10)
    else:
        sched = sched_cls(engine, num_slots=4, max_seq=64)
    m = sched.run(wl)
    assert len(m.responses) == 5
    assert all(len(r.tokens) == 3 for r in m.responses)
    assert m.total_tokens == 15
    for r in m.responses:
        assert r.done_s >= r.first_token_s >= r.start_s - 1e-9
        assert r.start_s >= r.arrival_s - 1e-9


def test_continuous_batching_matches_realtime_tokens(setup):
    """Batching must not change greedy outputs (order-independence)."""
    cfg, params = setup
    engine = CompiledEngine(cfg, params, max_seq=64)
    wl = synth_workload(4, 8, 3, cfg.vocab_size, rate_per_s=1000, seed=3)
    rt = RealTimeScheduler(engine).run(wl)
    cb = ContinuousBatchScheduler(engine, num_slots=2, max_seq=64).run(wl)
    rt_by_id = {r.rid: r.tokens for r in rt.responses}
    cb_by_id = {r.rid: r.tokens for r in cb.responses}
    for rid in rt_by_id:
        np.testing.assert_array_equal(rt_by_id[rid], cb_by_id[rid])


def test_server_wire_roundtrip(setup):
    cfg, params = setup
    dep = Deployment(arch=ARCH, si=ServingInfrastructure.SI3_DL_SERVER,
                     request_processing=RequestProcessing.DYNAMIC_BATCH,
                     max_batch=4, max_seq=64)
    srv = ServingServer(dep)
    url = srv.register(ModelPackage(name="m", arch=ARCH, params=params,
                                    max_seq=64))
    assert url == "/v1/models/m:predict"
    wl = synth_workload(3, 8, 2, cfg.vocab_size, rate_per_s=100, seed=2)
    wire = [
        (r.arrival_s,
         srv.codec.encode_request(r.rid, r.prompt, r.max_new_tokens))
        for r in wl
    ]
    out, metrics, stats = srv.handle_wire("m", wire)
    assert len(out) == 3
    assert stats.request_bytes > 0 and stats.response_bytes > 0


def test_formats_roundtrip(setup, tmp_path):
    cfg, params = setup
    # native npz
    formats.save_native(params, str(tmp_path / "m"))
    p1 = formats.load_native(params, str(tmp_path / "m"))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    # rsm
    formats.save_rsm(params, str(tmp_path / "rsm"))
    p2 = formats.load_rsm(params, str(tmp_path / "rsm"))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_int8_format_smaller_and_close(setup, tmp_path):
    cfg, params = setup
    full = formats.save_rsm(params, str(tmp_path / "full"), quantize=False)
    q = formats.save_rsm(params, str(tmp_path / "q"), quantize=True)
    assert q < full * 0.75  # int8 format is materially smaller (TD2)
    pq = formats.load_rsm(params, str(tmp_path / "q"))
    # dequantized params are close to the originals
    errs = []
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(pq)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        if a.ndim == 2 and a.size:
            denom = np.abs(a).mean() + 1e-9
            errs.append(np.abs(a - b).mean() / denom)
    assert max(errs) < 0.02


def test_int8_qtensor_serving(setup, tmp_path):
    """rsm_int8 + QTensor path generates tokens close to full precision."""
    cfg, params = setup
    formats.save_rsm(params, str(tmp_path / "q"), quantize=True)
    pq = formats.load_rsm(params, str(tmp_path / "q"), as_qtensor=True)
    tokens = np.random.RandomState(1).randint(0, cfg.vocab_size,
                                              (1, 8)).astype(np.int32)
    full_logits, _ = CompiledEngine(cfg, params, 16)._prefill(
        jnp.asarray(tokens))
    q_logits, _ = CompiledEngine(cfg, pq, 16)._prefill(jnp.asarray(tokens))
    corr = np.corrcoef(np.asarray(full_logits).ravel(),
                       np.asarray(q_logits).ravel())[0, 1]
    assert corr > 0.99, corr


def test_cloud_service(setup, tmp_path):
    cfg, params = setup
    cloud = CloudService(str(tmp_path / "registry"))
    cloud.upload_model("m", 1, params, ModelFormat.RSM)
    dep = Deployment(arch=ARCH, si=ServingInfrastructure.SI4_CLOUD_SERVICE,
                     request_processing=RequestProcessing.DYNAMIC_BATCH,
                     max_batch=4, max_seq=64, min_replicas=1, max_replicas=3)
    url = cloud.deploy("m", 1, dep, template_params=params)
    assert url.startswith("https://")
    wl = synth_workload(6, 8, 2, cfg.vocab_size, rate_per_s=50, seed=4)
    m = cloud.predict("m", wl, service_time_hint_s=0.05)
    assert len(m.responses) == 6
    assert cloud.endpoints["m"]["replicas"] >= 1
    assert cloud.registry.versions("m") == [1]


def test_container_artifacts():
    from repro.core.add import Containerization

    for c in Containerization:
        dep = Deployment(arch=ARCH, containerization=c)
        art = generate_artifact(dep)
        assert isinstance(art, str) and len(art) > 10
        ovh = overhead(c)
        assert ovh.energy_overhead >= 1.0
        assert ovh.simulated
    d = Deployment(arch=ARCH, containerization=Containerization.DOCKER)
    assert "FROM python" in generate_artifact(d)

"""Admission subsystem contract tests: priority classes, in-replica
preemption, prefill/decode disaggregation, carbon-biased scale-down.

The load-bearing invariants of the admission layer (PR 5):

  * the priority ladder reorders only *backlogged* queues (FIFO within a
    class; a ladder on an uncongested queue, or no ladder at all, is the
    pre-admission behavior bit for bit);
  * preemption really trades: the interactive TTFT drops, the preempted
    batch finishes late by exactly the interruption, and the pause/resume
    work is visible in the meter's ``preempt`` bucket;
  * joules AND grams conserve across pauses — per-request attribution sums
    to active, total = active + idle + preempt + xfer, and the fleet total
    decomposes into its per-replica sources — for every policy x router
    combo under the bursty flash-crowd workload, deterministically;
  * disaggregated endpoints serve every request exactly once (two legs
    stitched back into one response), the KV handoff is billed to ``xfer``
    on the sending replica, and a slower link costs strictly more;
  * ``AutoscaleSpec.carbon_bias`` shrinks pools harder on dirty windows
    without dropping work;
  * PrioritySpec / DisaggSpec round-trip through ServingSpec JSON, validate
    eagerly with field paths, and sweep like any other decision field.
"""

import dataclasses

import numpy as np
import pytest

from repro.carbon.signal import DiurnalSignal
from repro.core.engines import GenerationResult
from repro.serving.admission import (
    AdmissionControl,
    DisaggRuntime,
    DisaggSpec,
    PrioritySpec,
    kv_cache_bytes,
    priority_level,
)
from repro.serving.api import (
    AutoscaleSpec,
    EndpointSpec,
    ServingSession,
    ServingSpec,
    SLOClass,
    SpecError,
    sweep,
)
from repro.serving.core import SchedulerCore
from repro.serving.fleet import Autoscaler, ReplicaFleet
from repro.serving.fleet import EndpointSpec as FleetEndpoint
from repro.serving.request import Request, ServingMetrics
from repro.serving.scheduler import (
    POLICIES,
    DecodePhasePolicy,
    DynamicBatchPolicy,
    PrefillPhasePolicy,
    RealTimePolicy,
    make_policy,
)
from repro.workload.generators import bursty, poisson

ROUTERS = ("round_robin", "least_loaded", "warmest", "greenest",
           "carbon_aware")


class FakeEngine:
    """Deterministic timings, no model — admission mechanics only."""

    def __init__(self, prefill_s=0.01, step_s=0.005):
        self.prefill_s = prefill_s
        self.step_s = step_s
        self.cfg = type("Cfg", (), {"vocab_size": 1000})()

    def generate(self, tokens, max_new):
        B = tokens.shape[0]
        return GenerationResult(
            tokens=np.ones((B, max_new), np.int32),
            prefill_s=self.prefill_s,
            decode_s=self.step_s * (max_new - 1),
            n_steps=max_new,
        )


def req(rid, arrival_s=0.0, priority=None, max_new=8, prompt_len=8):
    return Request(rid=rid, prompt=np.arange(prompt_len, dtype=np.int32),
                   max_new_tokens=max_new, arrival_s=arrival_s,
                   priority=priority)


def assert_conserved_jg(m: ServingMetrics, rel=1e-6):
    """The PR-5 conservation contract: four buckets, both units."""
    meter = m.meter
    assert meter.total_j == pytest.approx(
        meter.active_j + meter.idle_j + meter.preempt_j + meter.xfer_j,
        rel=rel)
    assert meter.total_g == pytest.approx(
        meter.active_g + meter.idle_g + meter.preempt_g + meter.xfer_g,
        rel=rel)
    assert sum(meter.per_request_j.values()) == pytest.approx(
        meter.active_j, rel=rel)
    assert sum(meter.per_request_g.values()) == pytest.approx(
        meter.active_g, rel=rel)
    if meter.by_source:
        by_j = sum(d["active_j"] + d["idle_j"] + d["preempt_j"] + d["xfer_j"]
                   for d in meter.by_source.values())
        by_g = sum(d["active_g"] + d["idle_g"] + d["preempt_g"] + d["xfer_g"]
                   for d in meter.by_source.values())
        assert by_j == pytest.approx(meter.total_j, rel=rel)
        assert by_g == pytest.approx(meter.total_g, rel=rel)


# -- the ladder ----------------------------------------------------------------


def test_priority_levels_order():
    assert priority_level("interactive") < priority_level("standard")
    assert priority_level("standard") < priority_level("batch")
    assert priority_level(None) == priority_level("standard")
    with pytest.raises(ValueError, match="unknown priority class"):
        priority_level("vip")


def test_backlog_pops_most_urgent_first():
    adm = AdmissionControl(preempt=False)
    core = SchedulerCore(FakeEngine(), RealTimePolicy(), admission=adm)
    wl = [req(0, 0.0, "batch"), req(1, 0.0, "standard"),
          req(2, 0.0, "interactive")]
    m = core.run(wl)
    order = sorted(m.responses, key=lambda r: r.done_s)
    assert [r.rid for r in order] == [2, 1, 0]


def test_fifo_without_ladder_and_without_backlog():
    # no ladder: strict FIFO even with priorities stamped
    core = SchedulerCore(FakeEngine(), RealTimePolicy())
    wl = [req(0, 0.0, "batch"), req(1, 0.0, "interactive")]
    order = sorted(core.run(wl).responses, key=lambda r: r.done_s)
    assert [r.rid for r in order] == [0, 1]
    # ladder but no backlog (arrivals far apart): FIFO again
    adm = AdmissionControl(preempt=False)
    core = SchedulerCore(FakeEngine(), RealTimePolicy(), admission=adm)
    wl = [req(0, 0.0, "batch"), req(1, 10.0, "interactive")]
    order = sorted(core.run(wl).responses, key=lambda r: r.done_s)
    assert [r.rid for r in order] == [0, 1]


# -- preemption ----------------------------------------------------------------


def preempt_workload():
    # a long batch dispatch at t=0; an interactive request lands mid-decode
    return [req(0, 0.0, "batch", max_new=12),
            req(1, 0.04, "interactive", max_new=4)]


def run_core(admission):
    core = SchedulerCore(FakeEngine(),
                         DynamicBatchPolicy(max_batch=1, timeout_ms=0.0),
                         admission=admission)
    m = core.run(preempt_workload())
    return core, {r.rid: r for r in m.responses}, m


def test_preemption_trades_ttft_for_batch_delay_and_bills_preempt():
    _, fifo, _ = run_core(AdmissionControl(preempt=False))
    adm = AdmissionControl(preempt=True, pause_s=0.002, resume_s=0.002)
    core, pre, m = run_core(adm)
    # the interactive request jumps the in-flight decode
    assert pre[1].ttft_s < fifo[1].ttft_s
    # the preempted batch pays exactly the interruption: pause + the
    # urgent dispatch + resume
    urgent = core.step_cache  # unused; duration comes from the fake engine
    intr = adm.pause_s + (0.01 + 0.005 * 3) + adm.resume_s
    assert pre[0].done_s == pytest.approx(fifo[0].done_s + intr)
    # pause/resume work is visible in the preempt bucket
    assert core.meter.preempt_s == pytest.approx(adm.pause_s + adm.resume_s)
    assert core.meter.preempt_j > 0
    assert_conserved_jg(m)


def test_preemption_never_pauses_prefill():
    # the interactive request arrives DURING the batch's prefill: the pause
    # lands exactly at the prefill boundary, so the batch's first token is
    # unshifted
    adm = AdmissionControl(preempt=True, pause_s=0.001, resume_s=0.001)
    core = SchedulerCore(FakeEngine(prefill_s=0.05),
                         DynamicBatchPolicy(max_batch=1, timeout_ms=0.0),
                         admission=adm)
    wl = [req(0, 0.0, "batch", max_new=8), req(1, 0.01, "interactive",
                                               max_new=2)]
    m = core.run(wl)
    by = {r.rid: r for r in m.responses}
    assert by[0].first_token_s == pytest.approx(0.05)
    # and the urgent dispatch starts right after prefill + pause
    assert by[1].start_s == pytest.approx(0.05 + adm.pause_s)
    assert_conserved_jg(m)


def test_interactive_work_is_never_preempted():
    adm = AdmissionControl(preempt=True)
    core = SchedulerCore(FakeEngine(),
                         DynamicBatchPolicy(max_batch=1, timeout_ms=0.0),
                         admission=adm)
    wl = [req(0, 0.0, "interactive", max_new=12),
          req(1, 0.02, "interactive", max_new=2)]
    m = core.run(wl)
    assert core.meter.preempt_s == 0.0
    by = {r.rid: r for r in m.responses}
    assert by[1].start_s >= by[0].done_s  # plain FIFO, no pause


def test_max_preemptions_caps_interruptions():
    adm = AdmissionControl(preempt=True, max_preemptions=1)
    core = SchedulerCore(FakeEngine(),
                         DynamicBatchPolicy(max_batch=1, timeout_ms=0.0),
                         admission=adm)
    wl = [req(0, 0.0, "batch", max_new=12),
          req(1, 0.02, "interactive", max_new=2),
          req(2, 0.03, "interactive", max_new=2)]
    core.run(wl)
    # one pause+resume only; the second urgent request waits its turn
    assert core.meter.preempt_s == pytest.approx(
        adm.pause_s + adm.resume_s)


# -- conservation + determinism across the whole grid (satellite) --------------


def _mixed_flash_crowd(n=160):
    """Interactive chat + batch bulk whose flash crowds collide with it."""
    chat = poisson(n // 2, 8, 4, 1000, rate_per_s=300.0, seed=7,
                   priority="interactive", slo_ms=100.0)
    bulk = bursty(n // 2, 8, 6, 1000, rate_per_s=60.0, burst_n=40,
                  burst_every_s=0.5, burst_rate_per_s=800.0, seed=8,
                  rid0=10_000, priority="batch")
    return {"chat": chat, "bulk": bulk}


def _grid_fleet(router, policy):
    adm = AdmissionControl(preempt=True, pause_s=0.001, resume_s=0.001)
    fleet = ReplicaFleet(router=router,
                         autoscaler=Autoscaler(window_s=0.25,
                                               cold_start_s=0.05))
    for name in ("chat", "bulk"):
        fleet.add_endpoint(FleetEndpoint(
            name=name,
            engine=FakeEngine(),
            policy_factory=lambda policy=policy: make_policy(
                policy, max_batch=8, timeout_ms=10.0),
            min_replicas=1, max_replicas=3, initial_replicas=2,
            admission=adm,
        ))
    return fleet


@pytest.mark.parametrize("router", ROUTERS)
@pytest.mark.parametrize("policy", POLICIES)
def test_preemption_conserves_and_is_deterministic(policy, router):
    if policy == "continuous_batch":
        # slot admission needs a real KV cache; the fake engine exercises
        # the priority queue through the other three policies, and the
        # continuous path admits per-request (nothing batched to preempt)
        pytest.skip("continuous_batch needs a real engine KV cache")
    runs = []
    for _ in range(2):
        fleet = _grid_fleet(router, policy)
        res = fleet.run(_mixed_flash_crowd())
        assert len(res.fleet.responses) == 160
        assert_conserved_jg(res.fleet)
        for m in res.endpoints.values():
            assert_conserved_jg(m)
        runs.append(res)
    a, b = runs
    assert [r.rid for r in a.fleet.responses] == \
        [r.rid for r in b.fleet.responses]
    assert [r.done_s for r in a.fleet.responses] == pytest.approx(
        [r.done_s for r in b.fleet.responses])
    assert a.fleet.meter.total_j == pytest.approx(b.fleet.meter.total_j)
    assert a.fleet.meter.total_g == pytest.approx(b.fleet.meter.total_g)


# -- disaggregation ------------------------------------------------------------


def _disagg_runtime(link_gbps=10.0, latency_ms=0.2, power_w=15.0,
                    kv_per_tok=50_000.0, pools=(2, 2)):
    return DisaggRuntime.from_spec(
        DisaggSpec(enabled=True, prefill_replicas=pools[0],
                   decode_replicas=pools[1], link_gbps=link_gbps,
                   link_latency_ms=latency_ms, link_power_w=power_w,
                   kv_bytes_per_token=kv_per_tok),
        cfg=None,
        prefill_policy_factory=lambda: PrefillPhasePolicy(8, 5.0),
        decode_policy_factory=lambda: DecodePhasePolicy(8, 5.0),
    )


def _disagg_fleet(runtime, router="round_robin"):
    fleet = ReplicaFleet(router=router)
    fleet.add_endpoint(FleetEndpoint(
        name="llm", engine=FakeEngine(),
        policy_factory=lambda: DynamicBatchPolicy(8, 5.0),
        disagg=runtime,
    ))
    return fleet


def test_disagg_serves_all_and_stitches_legs():
    wl = poisson(80, 8, 6, 1000, rate_per_s=200.0, seed=3)
    fleet = _disagg_fleet(_disagg_runtime())
    res = fleet.run({"llm": wl})
    m = res.endpoints["llm"]
    assert {r.rid for r in m.responses} == {r.rid for r in wl}
    assert m.total_tokens == 80 * 6
    for r in m.responses:
        assert len(r.tokens) == 6        # both legs stitched
        assert r.arrival_s <= r.first_token_s <= r.done_s
    # every request with a decode phase paid exactly one handoff
    assert m.fleet["handoffs"]["count"] == 80
    assert m.meter.xfer_j > 0
    assert_conserved_jg(m)
    assert_conserved_jg(res.fleet)
    # prefill pool replicas never decode, decode replicas never prefill
    roles = {r.name: r.role for r in fleet.replicas}
    assert roles == {"llm/p0": "prefill", "llm/p1": "prefill",
                     "llm/d0": "decode", "llm/d1": "decode"}


def test_disagg_slower_link_costs_strictly_more():
    wl = poisson(60, 8, 6, 1000, rate_per_s=200.0, seed=4)
    fast = _disagg_fleet(_disagg_runtime(link_gbps=100.0, latency_ms=0.05))
    slow = _disagg_fleet(_disagg_runtime(link_gbps=0.5, latency_ms=5.0,
                                         power_w=40.0))
    mf = fast.run({"llm": wl}).endpoints["llm"]
    ms = slow.run({"llm": wl}).endpoints["llm"]
    assert ms.meter.xfer_j > mf.meter.xfer_j
    assert ms.meter.xfer_s > mf.meter.xfer_s
    # the slow link delays decode starts, so completion drifts later
    assert ms.latency_percentile(95) > mf.latency_percentile(95)
    # TTFT comes from the prefill leg and does not depend on the link
    assert ms.mean_ttft_s == pytest.approx(mf.mean_ttft_s)


def test_disagg_determinism():
    wl = poisson(50, 8, 6, 1000, rate_per_s=150.0, seed=5)
    a = _disagg_fleet(_disagg_runtime()).run({"llm": wl})
    b = _disagg_fleet(_disagg_runtime()).run({"llm": wl})
    assert [r.done_s for r in a.fleet.responses] == pytest.approx(
        [r.done_s for r in b.fleet.responses])
    assert a.fleet.meter.total_j == pytest.approx(b.fleet.meter.total_j)


def test_kv_cache_bytes_scales_with_arch_and_seq():
    cfg = type("Cfg", (), {"num_layers": 4, "num_kv_heads": 2,
                           "num_heads": 8, "head_dim": 16})()
    assert kv_cache_bytes(cfg, 1) == 2 * 4 * 2 * 16 * 2
    assert kv_cache_bytes(cfg, 10) == 10 * kv_cache_bytes(cfg, 1)
    assert kv_cache_bytes(cfg, 10, dtype_bytes=4) == \
        2 * kv_cache_bytes(cfg, 10)


# -- carbon-biased scale-down --------------------------------------------------


def _bias_fleet(bias):
    sig = DiurnalSignal(base_g_per_kwh=450.0, amplitude_g_per_kwh=400.0,
                        period_s=4.0)
    fleet = ReplicaFleet(router="round_robin",
                         autoscaler=Autoscaler(window_s=0.25,
                                               cold_start_s=0.05,
                                               down_windows=1),
                         carbon=sig)
    fleet.add_endpoint(FleetEndpoint(
        name="chat", engine=FakeEngine(),
        policy_factory=lambda: DynamicBatchPolicy(8, 10.0),
        min_replicas=1, max_replicas=6, initial_replicas=4,
        carbon_bias=bias,
    ))
    return fleet


def test_carbon_bias_shrinks_replica_seconds_without_drops():
    wl = {"chat": poisson(400, 8, 4, 1000, rate_per_s=150.0, seed=9)}
    plain = _bias_fleet(0.0).run(dict(wl))
    biased = _bias_fleet(3.0).run(dict(wl))
    assert len(plain.fleet.responses) == 400
    assert len(biased.fleet.responses) == 400
    rs_plain = plain.fleet.fleet["replica_seconds"]
    rs_biased = biased.fleet.fleet["replica_seconds"]
    assert rs_biased <= rs_plain
    assert_conserved_jg(biased.fleet)


# -- spec layer ----------------------------------------------------------------


def base_spec(**kw):
    defaults = dict(
        endpoints=(EndpointSpec(
            name="llm", arch="minitron-4b-smoke", model="m",
            policy="dynamic_batch", max_batch=4,
            # frozen pool: disagg.enabled sweeps require autoscale off
            autoscale=AutoscaleSpec(enabled=False, replicas_hint=2),
            slo_classes={"chat": SLOClass(slo_ms=100.0,
                                          priority="interactive"),
                         "bulk": SLOClass(priority="batch")},
        ),),
    )
    defaults.update(kw)
    return ServingSpec(**defaults)


def test_priority_and_disagg_round_trip_json():
    spec = base_spec(priority=PrioritySpec(enabled=True, preempt=True,
                                           pause_ms=1.5))
    spec = dataclasses.replace(
        spec,
        endpoints=(dataclasses.replace(
            spec.endpoints[0],
            disagg=DisaggSpec(enabled=True, prefill_replicas=2,
                              decode_replicas=3, link_gbps=10.0)),))
    spec.validate()
    back = ServingSpec.from_json(spec.to_json())
    assert back == spec
    assert back.priority.pause_ms == 1.5
    assert back.endpoints[0].disagg.decode_replicas == 3
    assert back.endpoints[0].slo_classes["chat"].priority == "interactive"


@pytest.mark.parametrize("mutate, path_frag", [
    (lambda s: dataclasses.replace(s, priority=PrioritySpec(pause_ms=-1)),
     "priority.pause_ms"),
    (lambda s: dataclasses.replace(
        s, endpoints=(dataclasses.replace(
            s.endpoints[0],
            disagg=DisaggSpec(enabled=True, link_gbps=0.0)),)),
     "disagg.link_gbps"),
    (lambda s: dataclasses.replace(
        s, endpoints=(dataclasses.replace(
            s.endpoints[0], si="si2_runtime",
            autoscale=AutoscaleSpec(max_replicas=1),
            disagg=DisaggSpec(enabled=True)),)),
     "disagg.enabled"),
    (lambda s: dataclasses.replace(
        s, endpoints=(dataclasses.replace(
            s.endpoints[0],
            slo_classes={"x": SLOClass(priority="vip")}),)),
     "slo_classes[x].priority"),
    (lambda s: dataclasses.replace(
        s, endpoints=(dataclasses.replace(
            s.endpoints[0],
            autoscale=AutoscaleSpec(carbon_bias=-0.5)),)),
     "autoscale.carbon_bias"),
])
def test_validation_names_the_offending_field(mutate, path_frag):
    with pytest.raises(SpecError) as e:
        mutate(base_spec()).validate()
    assert path_frag in e.value.field


def test_disagg_rejects_continuous_batch():
    spec = base_spec()
    spec = dataclasses.replace(
        spec, endpoints=(dataclasses.replace(
            spec.endpoints[0], policy="continuous_batch",
            disagg=DisaggSpec(enabled=True)),))
    with pytest.raises(SpecError) as e:
        spec.validate()
    assert "policy" in e.value.field


def test_admission_fields_are_sweepable():
    cells = sweep(base_spec(), {
        "priority.enabled": [False, True],
        "priority.preempt": [False, True],
        "endpoints.llm.disagg.enabled": [False, True],
    })
    assert len(cells) == 8
    assigns = {tuple(a.values()) for a, _ in cells}
    assert (True, True, True) in assigns


def test_session_stamps_priority_and_serves_disagg():
    """End-to-end through the declarative facade with an injected engine:
    SLO classes stamp priorities, the fleet splits phase pools, and the
    report carries the admission attribution."""
    spec = base_spec(priority=PrioritySpec(enabled=True))
    spec = dataclasses.replace(
        spec, endpoints=(dataclasses.replace(
            spec.endpoints[0],
            disagg=DisaggSpec(enabled=True, prefill_replicas=2,
                              decode_replicas=2, link_gbps=1.0,
                              link_latency_ms=1.0, link_power_w=20.0),
            autoscale=AutoscaleSpec(enabled=False, replicas_hint=2)),))
    session = ServingSession()
    session.deploy(spec, engines={"llm": FakeEngine()})
    session.submit("llm", poisson(40, 8, 6, 1000, rate_per_s=100.0, seed=11),
                   slo_class="chat")
    session.submit("llm", poisson(40, 8, 6, 1000, rate_per_s=60.0, seed=12,
                                  rid0=5_000),
                   slo_class="bulk")
    report = session.run()
    ep = report.endpoints["llm"]
    assert ep.n_requests == 80
    assert ep.decisions["disagg"] == "prefill/decode"
    assert ep.j_xfer > 0
    assert set(ep.ttft_p95_by_class) == {"interactive", "batch"}
    # conservation through the report's meter
    assert_conserved_jg(ep.metrics)

"""Additional system invariants (fast property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.energy.estimator import RooflineTerms, carbon_g, step_energy_j
from repro.kernels.int8_matmul import quantize_int8
from repro.models.moe import capacity, init_moe, moe_ffn, route
from repro.serving.request import synth_workload
from repro.serving.scheduler import DynamicBatchScheduler

SETTINGS = dict(max_examples=20, deadline=None)
KEY = jax.random.PRNGKey


# -- int8 quantization error bound ---------------------------------------------


@given(d=st.sampled_from([16, 64]), n=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_int8_roundtrip_error_bound(d, n, seed):
    w = jax.random.normal(KEY(seed % 2**31), (d, n))
    wq, sc = quantize_int8(w)
    back = np.asarray(wq, np.float32) * np.asarray(sc)[None, :]
    # symmetric per-channel: |err| <= scale/2 elementwise
    err = np.abs(back - np.asarray(w))
    assert (err <= np.asarray(sc)[None, :] * 0.5 + 1e-7).all()


# -- MoE: dropless dispatch-combine is exact ------------------------------------


@given(seed=st.integers(0, 2**30))
@settings(max_examples=10, deadline=None)
def test_moe_top1_dropless_exact(seed):
    E, D, F, T = 2, 8, 16, 12
    p = init_moe(KEY(seed % 2**31), D, F, E, jnp.float32)
    x = jax.random.normal(KEY((seed + 1) % 2**31), (1, T, D))
    out, _ = moe_ffn(p, x, experts_per_token=1, capacity_factor=float(E))
    gates, idx, _ = route(p["router"], x[0], 1)
    for t in range(T):
        e = int(idx[t, 0])
        v = x[0, t]
        h = jax.nn.silu(v @ p["wi_gate"][e]) * (v @ p["wi_up"][e])
        want = np.asarray(h @ p["wo"][e]) * float(gates[t, 0])
        np.testing.assert_allclose(np.asarray(out[0, t]), want, atol=1e-4,
                                   rtol=1e-4)


@given(t=st.integers(8, 4096), e=st.sampled_from([2, 8, 128]),
       k=st.sampled_from([1, 2]),
       cf=st.floats(0.5, 8.0))
@settings(**SETTINGS)
def test_moe_capacity_bounds(t, e, k, cf):
    c = capacity(t, e, k, cf)
    assert c >= 8 and c % 8 == 0
    # monotone in tokens and slack factor
    assert capacity(t * 2, e, k, cf) >= c
    assert capacity(t, e, k, cf * 2) >= c
    # tight within one rounding unit of the analytic value
    assert c <= max(8, int(t * k * cf / e) + 8)


# -- scheduler FIFO/causality ------------------------------------------------------


def test_dynamic_batch_causality_and_fifo():
    class FakeEngine:
        cfg = None

        def generate(self, tokens, max_new):
            import numpy as np

            from repro.core.engines import GenerationResult

            B = tokens.shape[0]
            return GenerationResult(
                tokens=np.zeros((B, max_new), np.int32),
                prefill_s=0.01, decode_s=0.01 * max_new, n_steps=max_new,
            )

    wl = synth_workload(9, 8, 2, 100, rate_per_s=30, seed=3)
    m = DynamicBatchScheduler(FakeEngine(), max_batch=4, timeout_ms=5).run(wl)
    assert len(m.responses) == 9
    for r in m.responses:
        assert r.start_s >= r.arrival_s - 1e-9          # causality
        assert r.done_s >= r.start_s
    # batches retire in arrival order
    by_rid = sorted(m.responses, key=lambda r: r.rid)
    dones = [r.done_s for r in by_rid]
    assert dones == sorted(dones)


# -- energy model -------------------------------------------------------------------


@given(flops=st.floats(1e9, 1e16), bts=st.floats(1e6, 1e14),
       coll=st.floats(0, 1e13))
@settings(**SETTINGS)
def test_energy_monotone_in_time(flops, bts, coll):
    a = RooflineTerms(flops=flops, hbm_bytes=bts, collective_bytes=coll,
                      chips=256)
    b = RooflineTerms(flops=flops * 2, hbm_bytes=bts * 2,
                      collective_bytes=coll * 2, chips=256)
    assert step_energy_j(b) >= step_energy_j(a) - 1e-9
    assert carbon_g(step_energy_j(a)) >= 0

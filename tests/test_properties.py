"""Hypothesis property-based tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.add import (
    Containerization,
    Deployment,
    ModelFormat,
    Protocol,
    RequestProcessing,
    ServingInfrastructure,
)
from repro.energy.estimator import RooflineTerms, step_energy_j, step_power_w
from repro.kernels import ops, ref
from repro.serving.codecs import BinaryCodec, JsonCodec
from repro.serving.request import synth_workload
from repro.training.optim import AdamWConfig, schedule_lr

SETTINGS = dict(max_examples=25, deadline=None)


# -- codecs: roundtrip is identity; binary never larger than json --------------


@given(
    rid=st.integers(0, 2**31 - 1),
    tokens=st.lists(st.integers(0, 2**31 - 1), min_size=0, max_size=64),
    max_new=st.integers(1, 4096),
)
@settings(**SETTINGS)
def test_codec_roundtrip(rid, tokens, max_new):
    arr = np.asarray(tokens, np.int32)
    for codec in (JsonCodec(), BinaryCodec()):
        r2, a2, m2 = codec.decode_request(codec.encode_request(rid, arr, max_new))
        assert r2 == rid and m2 == max_new
        np.testing.assert_array_equal(a2, arr)
        r3, a3 = codec.decode_response(codec.encode_response(rid, arr))
        assert r3 == rid
        np.testing.assert_array_equal(a3, arr)


@given(tokens=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=128))
@settings(**SETTINGS)
def test_binary_never_larger(tokens):
    arr = np.asarray(tokens, np.int32)
    j = len(JsonCodec().encode_request(1, arr, 16))
    b = len(BinaryCodec().encode_request(1, arr, 16))
    assert b <= j


# -- deployment validation ------------------------------------------------------


@given(
    si=st.sampled_from(list(ServingInfrastructure)),
    cont=st.sampled_from(list(Containerization)),
    fmt=st.sampled_from(list(ModelFormat)),
    rp=st.sampled_from(list(RequestProcessing)),
    proto=st.sampled_from(list(Protocol)),
    mb=st.integers(1, 64),
)
@settings(**SETTINGS)
def test_deployment_validation_total(si, cont, fmt, rp, proto, mb):
    """validate() never crashes and is consistent with require_valid()."""
    dep = Deployment(arch="yi-9b", si=si, containerization=cont,
                     model_format=fmt, request_processing=rp, protocol=proto,
                     max_batch=mb)
    errs = dep.validate()
    assert isinstance(errs, list)
    if not errs:
        dep.require_valid()
    # realtime with batch>1 must always be rejected
    if rp == RequestProcessing.REALTIME and mb != 1:
        assert errs


# -- roofline estimator ----------------------------------------------------------


@given(
    flops=st.floats(1e6, 1e18),
    bts=st.floats(1e3, 1e15),
    coll=st.floats(0, 1e15),
    chips=st.sampled_from([1, 16, 256, 512]),
)
@settings(**SETTINGS)
def test_roofline_invariants(flops, bts, coll, chips):
    t = RooflineTerms(flops=flops, hbm_bytes=bts, collective_bytes=coll,
                      chips=chips)
    assert t.t_step >= max(t.t_compute, t.t_memory, t.t_collective) - 1e-15
    assert t.bottleneck in ("compute", "memory", "collective")
    p = step_power_w(t)
    assert t.chip.power_membound_w - 1e-9 <= p <= t.chip.power_peak_w + 1e-9
    assert step_energy_j(t) >= 0
    # more chips never increases per-term time
    t2 = RooflineTerms(flops=flops, hbm_bytes=bts, collective_bytes=coll,
                       chips=chips * 2)
    assert t2.t_step <= t.t_step + 1e-15


# -- optimizer schedule -----------------------------------------------------------


@given(step=st.integers(0, 20000))
@settings(**SETTINGS)
def test_lr_schedule_bounds(step):
    cfg = AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10000)
    lr = float(schedule_lr(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.lr * (1 + 1e-5)
    if step >= cfg.total_steps:
        assert abs(lr - cfg.lr * cfg.min_lr_frac) < 1e-8


# -- attention: flash == reference on random shapes -------------------------------


@given(
    b=st.integers(1, 2),
    k=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([16, 48, 64]),
    data=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_flash_matches_ref_property(b, k, g, s, data):
    key = jax.random.PRNGKey(data % 2**31)
    ks = jax.random.split(key, 3)
    dh = 16
    q = jax.random.normal(ks[0], (b, k * g, s, dh))
    kk = jax.random.normal(ks[1], (b, k, s, dh))
    v = jax.random.normal(ks[2], (b, k, s, dh))
    o = ops.flash_attention(q, kk, v, causal=True, block_q=16, block_kv=16)
    r = ref.flash_attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-4,
                               rtol=2e-4)


# -- workload generator ------------------------------------------------------------


@given(n=st.integers(1, 50), rate=st.floats(0.1, 100))
@settings(**SETTINGS)
def test_workload_sorted_and_deterministic(n, rate):
    a = synth_workload(n, 8, 4, 1000, rate, seed=7)
    b = synth_workload(n, 8, 4, 1000, rate, seed=7)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr) and arr[0] == 0.0
    assert all(0 <= t < 1000 for r in a for t in r.prompt)

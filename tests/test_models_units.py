"""Unit tests: layers, rope, attention chunking, MoE dispatch, SSM, training."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.models import forward, init_params
from repro.models.attention import attention, attention_reference
from repro.models.layers import rms_norm
from repro.models.moe import capacity, moe_ffn, init_moe, route
from repro.models.rope import (
    apply_rotary,
    mrope_angles,
    positions_default,
    rope_angles,
)
from repro.models.ssm import (
    init_mamba2_layer,
    init_rwkv6_layer,
    mamba2_block,
    rwkv6_block,
)

KEY = jax.random.PRNGKey


# -- attention chunking ---------------------------------------------------------


@pytest.mark.parametrize("S,block", [(64, 16), (60, 16), (128, 128)])
@pytest.mark.parametrize("window", [None, 13])
def test_chunked_attention_matches_reference(S, block, window):
    B, H, K, dh = 2, 4, 2, 16
    q = jax.random.normal(KEY(0), (B, S, H, dh))
    k = jax.random.normal(KEY(1), (B, S, K, dh))
    v = jax.random.normal(KEY(2), (B, S, K, dh))
    o = attention(q, k, v, causal=True, window=window, block_kv=block)
    r = attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_attention_kv_lengths_mask():
    B, S, H, K, dh = 2, 32, 2, 2, 8
    q = jax.random.normal(KEY(3), (B, 1, H, dh))
    k = jax.random.normal(KEY(4), (B, S, K, dh))
    v = jax.random.normal(KEY(5), (B, S, K, dh))
    lengths = jnp.array([5, 32], jnp.int32)
    o = attention(q, k, v, causal=False, kv_lengths=lengths,
                  q_offset=lengths - 1, block_kv=8)
    # manually truncate: request 0 must only see the first 5 kv entries
    o_trunc = attention(q[:1], k[:1, :5], v[:1, :5], causal=False,
                        q_offset=jnp.array([4]), block_kv=8)
    np.testing.assert_allclose(np.asarray(o[0]), np.asarray(o_trunc[0]),
                               atol=1e-5, rtol=1e-5)


# -- rope -------------------------------------------------------------------------


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(KEY(6), (2, 8, 4, 32))
    ang = rope_angles(positions_default(2, 8), 32, 1e4)
    y = apply_rotary(x, ang)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
    )


def test_rope_relative_property():
    """<q_m, k_n> depends only on m - n."""
    dh = 16
    q = jax.random.normal(KEY(7), (1, 1, 1, dh))
    k = jax.random.normal(KEY(8), (1, 1, 1, dh))

    def dot_at(m, n):
        qa = apply_rotary(q, rope_angles(jnp.array([[m]]), dh, 1e4))
        ka = apply_rotary(k, rope_angles(jnp.array([[n]]), dh, 1e4))
        return float(jnp.sum(qa * ka))

    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(7, 0) - dot_at(17, 10)) < 1e-4


def test_mrope_text_equals_rope():
    """Identical t/h/w ids (text tokens) must reduce to plain RoPE."""
    B, S, hd = 2, 6, 32
    pos = positions_default(B, S)
    a1 = rope_angles(pos, hd, 1e4)
    a2 = mrope_angles(jnp.stack([pos, pos, pos]), hd, 1e4, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)


# -- moe ---------------------------------------------------------------------------


def test_moe_capacity_monotone():
    assert capacity(1024, 8, 2, 1.25) >= capacity(1024, 8, 2, 1.0)
    assert capacity(1024, 8, 2, 1.25) % 8 == 0


def test_moe_route_normalized():
    p = init_moe(KEY(9), 32, 64, 8, jnp.float32)
    x = jax.random.normal(KEY(10), (16, 32))
    gates, idx, aux = route(p["router"], x, 2)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 8 and float(aux) > 0


def test_moe_ffn_matches_dense_per_expert():
    """With ample capacity, MoE == per-token dense mix of chosen experts."""
    E, D, F, T = 4, 16, 32, 8
    p = init_moe(KEY(11), D, F, E, jnp.float32)
    x = jax.random.normal(KEY(12), (1, T, D))
    out, aux = moe_ffn(p, x, experts_per_token=2, capacity_factor=8.0)
    gates, idx, _ = route(p["router"], x[0], 2)

    def expert_fwd(e, v):
        h = jax.nn.silu(v @ p["wi_gate"][e]) * (v @ p["wi_up"][e])
        return h @ p["wo"][e]

    want = np.zeros((T, D), np.float32)
    for t in range(T):
        for j in range(2):
            want[t] += float(gates[t, j]) * np.asarray(
                expert_fwd(int(idx[t, j]), x[0, t])
            )
    np.testing.assert_allclose(np.asarray(out[0]), want, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor -> tiny, some tokens must be dropped (output 0)."""
    E, D, F, T = 2, 8, 16, 64
    p = init_moe(KEY(13), D, F, E, jnp.float32)
    x = jax.random.normal(KEY(14), (1, T, D))
    out_full, _ = moe_ffn(p, x, experts_per_token=1, capacity_factor=8.0)
    out_tiny, _ = moe_ffn(p, x, experts_per_token=1, capacity_factor=0.1)
    # tiny capacity zeroes most rows
    zero_rows = np.sum(np.all(np.abs(np.asarray(out_tiny[0])) < 1e-9, axis=-1))
    assert zero_rows > T // 2


# -- ssm ----------------------------------------------------------------------------


def test_rwkv6_block_streaming_equals_batch():
    """Running T steps through the cache == one full-sequence pass."""
    D, F, hd = 32, 64, 16
    p = init_rwkv6_layer(KEY(15), D, F, hd, jnp.float32)
    B, T = 1, 6
    x = jax.random.normal(KEY(16), (B, T, D)) * 0.5
    y_full, _ = rwkv6_block(p, x, hd)
    cache = None
    ys = []
    for t in range(T):
        y, cache = rwkv6_block(p, x[:, t:t + 1], hd, cache=cache)
        ys.append(y)
    y_stream = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_stream),
                               atol=2e-4, rtol=2e-3)


def test_mamba2_block_streaming_equals_batch():
    D, di, S, hd = 32, 64, 16, 16
    p = init_mamba2_layer(KEY(17), D, di, S, hd, jnp.float32)
    B, T = 1, 6
    x = jax.random.normal(KEY(18), (B, T, D)) * 0.5
    y_full, _ = mamba2_block(p, x, head_dim=hd, ssm_state=S)
    cache = {"conv": jnp.zeros((B, 3, di + 2 * S)),
             "ssm": jnp.zeros((B, di // hd, hd, S))}
    ys = []
    for t in range(T):
        y, cache = mamba2_block(p, x[:, t:t + 1], head_dim=hd, ssm_state=S,
                                cache=cache)
        ys.append(y)
    y_stream = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_stream),
                               atol=2e-4, rtol=2e-3)


# -- misc ---------------------------------------------------------------------------


def test_rms_norm_scale_invariant_direction():
    x = jax.random.normal(KEY(19), (4, 32))
    w = jnp.ones((32,))
    y1 = rms_norm(x, w)
    y2 = rms_norm(3.0 * x, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_unroll_equals_scan():
    cfg = smoke_variant(get_arch("qwen3-8b"))
    params = init_params(cfg, KEY(20))
    batch = {"tokens": jax.random.randint(KEY(21), (2, 8), 0, cfg.vocab_size)}
    a = forward(params, cfg, batch)["logits"]
    b = forward(params, dataclasses.replace(cfg, unroll_layers=True),
                batch)["logits"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_sliding_window_decode_slices_cache():
    """Windowed decode (gather path) == full-cache decode with window mask."""
    import repro.models.transformer as T

    cfg = dataclasses.replace(smoke_variant(get_arch("mixtral-8x7b")),
                              attn_window=8)
    params = init_params(cfg, KEY(22))
    tokens = jax.random.randint(KEY(23), (2, 12), 0, cfg.vocab_size)
    from repro.models import decode_step, prefill

    # max_seq 64 > 2*window triggers the gather path
    lg, cache = prefill(params, cfg, {"tokens": tokens}, max_seq=64)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    l1, _ = decode_step(params, cfg, cache, tok)
    # force the mask path by shrinking max_seq below 2*window
    lg2, cache2 = prefill(params, cfg, {"tokens": tokens}, max_seq=14)
    l2, _ = decode_step(params, cfg, cache2, tok)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3,
                               rtol=2e-3)

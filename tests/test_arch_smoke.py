"""Per-arch smoke tests (required): reduced variant of each assigned family
runs one forward/train step and a prefill+decode on CPU; shapes + no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch, smoke_variant
from repro.models import decode_step, forward, init_params, prefill
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.trainer import make_train_step

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, key, B=2, S=16, labels=False):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def smoke_params():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_variant(get_arch(name))
            cache[name] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nan(arch, smoke_params):
    cfg, params = smoke_params(arch)
    B, S = 2, 16
    batch = _batch(cfg, jax.random.PRNGKey(1), B, S)
    out = forward(params, cfg, batch)
    assert out["logits"].shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(out["logits"]).any())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step(arch, smoke_params):
    cfg, params = smoke_params(arch)
    batch = _batch(cfg, jax.random.PRNGKey(2), 2, 16, labels=True)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1,
                                                    total_steps=10)))
    opt = init_opt_state(params)
    new_params, new_opt, stats = step(params, opt, batch)
    assert jnp.isfinite(stats["loss"])
    assert int(new_opt["step"]) == 1
    # params actually changed
    a = jax.tree.leaves(params)[0]
    b = jax.tree.leaves(new_params)[0]
    assert not jnp.allclose(a, b)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode(arch, smoke_params):
    cfg, params = smoke_params(arch)
    B, S = 2, 12
    batch = _batch(cfg, jax.random.PRNGKey(3), B, S)
    logits, cache = prefill(params, cfg, batch, max_seq=32)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert not bool(jnp.isnan(logits).any())
    assert int(cache["lengths"][0]) == S + 3


@pytest.mark.parametrize("arch", ["yi-9b", "qwen3-8b", "rwkv6-3b",
                                  "zamba2-2.7b", "mixtral-8x7b"])
def test_decode_matches_forward(arch, smoke_params):
    """THE serving invariant: stepping the cache reproduces full-seq logits."""
    cfg, params = smoke_params(arch)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                                cfg.vocab_size)
    full = forward(params, cfg, {"tokens": tokens})["logits"]
    # prefill on the first S-3 tokens, decode the last 3
    logits, cache = prefill(params, cfg, {"tokens": tokens[:, : S - 3]},
                            max_seq=32)
    got = [logits]
    for i in range(S - 3, S):
        logits, cache = decode_step(params, cfg, cache, tokens[:, i])
        got.append(logits)
    for j, g in enumerate(got[:-1]):
        ref = full[:, S - 4 + j]
        err = jnp.max(jnp.abs(g - ref))
        assert err < 2e-2, (j, float(err))


def test_train_loss_decreases():
    cfg = smoke_variant(get_arch("minitron-4b"))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    it = SyntheticLM(dcfg).batches()
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=5,
                                                    total_steps=50)))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    losses = []
    for _ in range(30):
        params, opt, stats = step(params, opt, next(it))
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_moe_aux_loss_present():
    cfg = smoke_variant(get_arch("mixtral-8x7b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(5))
    out = forward(params, cfg, batch)
    assert float(out["aux_loss"]) > 0.0

"""Green-SRE monitor contract tests (PR 10).

Pins the contracts the monitoring layer is built on:

  * **spec hygiene** — BudgetSpec/MonitorSpec validation with field paths,
    and the ServingSpec cross-checks (monitor needs telemetry; budget
    endpoint scopes must exist);
  * **burn-rate arithmetic** — each budget kind's burn on synthetic
    windows (slo ratio, energy rates, crash allowance, rated-power
    compliance), the fast+slow multi-window gate, and budget remaining;
  * **incident mechanics** — episode merging across quiet gaps, severity
    escalation, energy attribution;
  * **observer purity (R6)** — a monitored run is bit-identical in
    joules, grams and latencies to an unmonitored one, including under a
    chaos script, and the ``observation_guard`` raises if the stream is
    written mid-observation;
  * **alert determinism (R6)** — finalize's batch replay reproduces the
    incremental alert stream exactly, and fails loudly when tampered;
  * **detection** — a scripted crash pages the crashes budget while the
    identical healthy fleet stays silent;
  * **scoring + dashboard** — ``bench_monitor.score_detections`` units
    and a render smoke test of the stdlib HTML dashboard.
"""

import numpy as np
import pytest

from repro.core.engines import GenerationResult
from repro.energy.sanitize import ConservationError, observation_guard
from repro.serving.chaos import (ChaosEvent, ChaosRuntime, ChaosSpec,
                                 RetryRuntime, RetrySpec)
from repro.serving.fleet import Autoscaler, EndpointSpec, ReplicaFleet
from repro.serving.monitor import (BudgetSpec, BurnEngine, IncidentDetector,
                                   MonitorRuntime, MonitorSpec,
                                   render_dashboard, write_dashboard)
from repro.serving.scheduler import make_policy
from repro.serving.telemetry import TraceRecorder
from repro.workload.generators import bursty, poisson


class FakeEngine:
    """Deterministic timings, no model — monitor mechanics only."""

    def __init__(self, prefill_s=0.01, step_s=0.005):
        self.prefill_s = prefill_s
        self.step_s = step_s
        self.cfg = type("Cfg", (), {"vocab_size": 1000})()

    def generate(self, tokens, max_new):
        B = tokens.shape[0]
        return GenerationResult(
            tokens=np.ones((B, max_new), np.int32),
            prefill_s=self.prefill_s,
            decode_s=self.step_s * (max_new - 1),
            n_steps=max_new,
        )


def _mixed_crowd(n=120):
    chat = poisson(n // 2, 8, 4, 1000, rate_per_s=300.0, seed=7,
                   priority="interactive", slo_ms=100.0)
    bulk = bursty(n // 2, 8, 6, 1000, rate_per_s=60.0, burst_n=20,
                  burst_every_s=0.5, burst_rate_per_s=800.0, seed=8,
                  rid0=10_000, priority="batch")
    return {"chat": chat, "bulk": bulk}


SLO_TARGETS = {("chat", "interactive"): (100.0, 0.0),
               ("bulk", "batch"): (0.0, 5.0)}

BUDGETS = (
    BudgetSpec(name="crashes", kind="crashes", budget=1.0, horizon_s=60.0,
               fast_window_s=0.5, slow_window_s=1.0,
               page_burn=50.0, warn_burn=10.0),
    BudgetSpec(name="loss", kind="loss", budget=0.5, horizon_s=10.0,
               fast_window_s=0.5, slow_window_s=1.0,
               page_burn=5.0, warn_burn=1.0),
    BudgetSpec(name="slo-int", kind="slo", slo_class="interactive",
               objective=0.9, fast_window_s=0.5, slow_window_s=1.0,
               page_burn=8.0, warn_burn=2.0),
)


def _fleet(telemetry=None, monitor=None, chaos=False):
    kwargs = {}
    if chaos:
        kwargs["chaos"] = ChaosRuntime.from_spec(ChaosSpec(
            events=(ChaosEvent(kind="crash", t_s=0.15),
                    ChaosEvent(kind="crash", t_s=0.5)), seed=11))
        kwargs["retry"] = RetryRuntime.from_spec(
            RetrySpec(max_retries=3, backoff_s=0.02))
    fleet = ReplicaFleet(router="least_loaded",
                         autoscaler=Autoscaler(window_s=0.25,
                                               cold_start_s=0.05),
                         telemetry=telemetry, monitor=monitor, **kwargs)
    for name in ("chat", "bulk"):
        fleet.add_endpoint(EndpointSpec(
            name=name, engine=FakeEngine(),
            policy_factory=lambda: make_policy("dynamic_batch", max_batch=8,
                                               timeout_ms=10.0),
            min_replicas=2, max_replicas=3, initial_replicas=2,
        ))
    return fleet


def _monitored_run(chaos=False, budgets=BUDGETS, window_s=0.1):
    rec = TraceRecorder()
    mon = MonitorRuntime(MonitorSpec(enabled=True, window_s=window_s,
                                     budgets=budgets,
                                     incident_gap_s=0.3),
                         rec, SLO_TARGETS)
    res = _fleet(telemetry=rec, monitor=mon, chaos=chaos).run(_mixed_crowd())
    mon.finalize()
    return res, mon


# -- spec hygiene -------------------------------------------------------------

def test_budget_spec_problems():
    fields = lambda b: {f for f, _ in b.problems()}  # noqa: E731
    assert "name" in fields(BudgetSpec(name=""))
    assert "kind" in fields(BudgetSpec(name="x", kind="vibes"))
    # ratio kinds demand a real objective; energy kinds ignore it
    assert "objective" in fields(BudgetSpec(name="x", kind="power",
                                            budget=65.0, objective=1.0))
    assert "objective" not in fields(BudgetSpec(name="x", kind="joules",
                                                budget=1.0, objective=1.0))
    # every non-slo kind needs a positive budget (power: rated watts)
    for kind in ("joules", "grams", "loss", "crashes", "power"):
        b = BudgetSpec(name="x", kind=kind, budget=0.0, objective=0.5)
        assert "budget" in fields(b), kind
    assert "slow_window_s" in fields(BudgetSpec(
        name="x", fast_window_s=2.0, slow_window_s=1.0))
    assert "slow_window_s" in fields(BudgetSpec(
        name="x", slow_window_s=90.0, horizon_s=60.0))
    assert "page_burn" in fields(BudgetSpec(name="x", page_burn=1.0,
                                            warn_burn=2.0))
    assert not BudgetSpec(name="ok", kind="power", budget=65.0,
                          objective=0.95).problems()


def test_monitor_spec_problems():
    dup = MonitorSpec(budgets=(BudgetSpec(name="a"), BudgetSpec(name="a")))
    assert any("duplicate" in msg for _, msg in dup.problems())
    fine_grained = MonitorSpec(window_s=0.5, budgets=(
        BudgetSpec(name="a", fast_window_s=0.25),))
    assert any("finer" in msg for _, msg in fine_grained.problems())
    assert MonitorSpec(window_s=0.0).problems()
    assert not MonitorSpec(budgets=BUDGETS).problems()


def test_serving_spec_cross_checks():
    from repro.serving.api import ServingSpec, SpecError
    from repro.serving.api import EndpointSpec as ApiEndpointSpec
    ep = ApiEndpointSpec(name="llm", arch="minitron-4b-smoke", model="m")
    base = ServingSpec(endpoints=(ep,))
    # monitor consumes the telemetry stream
    with pytest.raises(SpecError, match="telemetry"):
        from repro.serving.api import with_override
        with_override(base, "monitor",
                      MonitorSpec(enabled=True)).validate()
    # budget endpoint scopes must name a declared endpoint
    from repro.serving.api import with_override
    spec = with_override(base, "telemetry.enabled", True)
    bad = with_override(spec, "monitor", MonitorSpec(
        enabled=True, budgets=(BudgetSpec(name="x", endpoint="ghost"),)))
    with pytest.raises(SpecError, match="ghost"):
        bad.validate()
    # an slo_class the endpoints never declare is allowed (workload
    # priorities are legitimate classes), so this validates cleanly
    ok = with_override(spec, "monitor", MonitorSpec(
        enabled=True, budgets=(BudgetSpec(name="x", kind="slo",
                                          slo_class="interactive"),)))
    ok.validate()


# -- burn-rate arithmetic on synthetic windows --------------------------------

def _win(idx, window_s=0.25, bad=0, served=0, crashes=0, lost_j=0.0,
         j=0.0, power_hist=None, active_s=0.0):
    t0 = idx * window_s
    return {"t0": t0, "t1": t0 + window_s, "served": served,
            "good": served - bad, "bad": bad, "classes": {}, "endpoints": {},
            "j": j, "g": 0.0, "tokens": 0, "lost_j": lost_j, "lost_g": 0.0,
            "buckets_j": {"active": j}, "active_s": active_s,
            "power_w_hist": power_hist or {}, "crashes": crashes,
            "drops": 0, "sheds": 0, "retries": 0, "gauges": {},
            "late_events": 0}


def test_crashes_kind_pages_on_one_crash():
    spec = BudgetSpec(name="c", kind="crashes", budget=1.0, horizon_s=60.0,
                      fast_window_s=0.5, slow_window_s=1.0,
                      page_burn=50.0, warn_burn=10.0)
    eng = BurnEngine([spec], 0.25)
    for i in range(3):
        assert eng.on_window(_win(i)) == []
    alerts = eng.on_window(_win(3, crashes=1))
    # fast: 1 crash / 0.5 s vs 1/60 sustainable = burn 120; slow: 1 / 1 s
    assert alerts and alerts[0]["severity"] == "page"
    assert alerts[0]["burn_fast"] == pytest.approx(120.0)
    assert alerts[0]["burn_slow"] == pytest.approx(60.0)


def test_power_kind_reads_capped_seconds_exactly():
    spec = BudgetSpec(name="p", kind="power", budget=65.0, objective=0.95,
                      fast_window_s=0.5, slow_window_s=0.5,
                      page_burn=8.0, warn_burn=2.0)
    eng = BurnEngine([spec], 0.25)
    # healthy: every active second billed at the rated wattage -> burn 0
    w = _win(0, power_hist={65.0: 0.4}, active_s=0.4)
    assert eng.on_window(w) == []
    assert w["burn"]["p"] == (0.0, 0.0)
    # brownout: capped seconds enter the fast window (half capped ->
    # ratio 0.5 -> burn 10), then saturate it (ratio 1.0 -> burn 20)
    alerts = eng.on_window(_win(1, power_hist={39.0: 0.4}, active_s=0.4))
    assert alerts and alerts[0]["severity"] == "page"
    assert alerts[0]["burn_fast"] == pytest.approx(10.0)
    alerts = eng.on_window(_win(2, power_hist={39.0: 0.4}, active_s=0.4))
    assert alerts[0]["burn_fast"] == pytest.approx(20.0)


def test_slo_kind_multi_window_gate_kills_flapping():
    spec = BudgetSpec(name="s", kind="slo", objective=0.9,
                      fast_window_s=0.25, slow_window_s=1.0,
                      page_burn=5.0, warn_burn=5.0)
    eng = BurnEngine([spec], 0.25)
    # a single bad window spikes the fast burn to 10 but the slow burn
    # (averaged over 4 windows of mostly-good traffic) stays below 5
    for i in range(3):
        assert eng.on_window(_win(i, served=30)) == []
    w = _win(3, served=30, bad=30)
    assert eng.on_window(w) == []
    assert w["burn"]["s"][0] == pytest.approx(10.0)
    assert w["burn"]["s"][1] < 5.0
    # sustained errors clear both windows -> page
    alerts = []
    for i in range(4, 8):
        alerts += eng.on_window(_win(i, served=30, bad=30))
    assert alerts and alerts[-1]["severity"] == "page"


def test_loss_kind_and_budget_remaining():
    spec = BudgetSpec(name="l", kind="loss", budget=1.0, horizon_s=10.0,
                      fast_window_s=0.5, slow_window_s=0.5,
                      page_burn=5.0, warn_burn=1.0)
    eng = BurnEngine([spec], 0.25)
    eng.on_window(_win(0, lost_j=0.3))
    eng.on_window(_win(1, lost_j=0.3))
    rem = eng.budget_remaining()["l"]
    assert rem["spent"] == pytest.approx(0.6)
    assert rem["remaining"] == pytest.approx(0.4)
    assert rem["remaining_frac"] == pytest.approx(0.4)
    # ratio kinds earn allowance with traffic served
    s = BudgetSpec(name="s", kind="slo", objective=0.9,
                   fast_window_s=0.25, slow_window_s=0.25)
    e2 = BurnEngine([s], 0.25)
    e2.on_window(_win(0, served=100, bad=5))
    rem = e2.budget_remaining()["s"]
    assert rem["budget"] == pytest.approx(10.0)   # (1-0.9) * 100
    assert rem["remaining"] == pytest.approx(5.0)


# -- incident mechanics -------------------------------------------------------

def _alert(t, budget="b", severity="warn", endpoint=""):
    return {"t": t, "budget": budget, "kind": "slo", "severity": severity,
            "endpoint": endpoint, "burn_fast": 9.9, "burn_slow": 9.9}


def test_incident_merge_gap_and_escalation():
    det = IncidentDetector(gap_s=0.5)
    det.on_window(_win(0), [_alert(0.25, severity="warn")])
    det.on_window(_win(1), [])                    # 0.25 s quiet < gap
    det.on_window(_win(2), [_alert(0.75, budget="c", severity="page")])
    for i in range(3, 7):
        det.on_window(_win(i), [])                # > gap: episode closes
    det.on_window(_win(7, lost_j=0.2), [_alert(2.0)])
    incidents = det.finalize()
    assert len(incidents) == 2
    first, second = incidents
    assert first["severity"] == "page"            # escalated warn -> page
    assert first["budgets"] == ["b", "c"]
    assert first["start"] == pytest.approx(0.0)
    assert first["end"] == pytest.approx(0.75)
    assert second["lost_j"] == pytest.approx(0.2)
    assert second["duration_s"] == pytest.approx(0.25)


# -- observer purity + determinism (R6) ---------------------------------------

def _fingerprint(res):
    m = res.fleet.meter
    lat = tuple((r.rid, r.done_s, r.first_token_s)
                for ep in res.endpoints.values() for r in ep.responses)
    return (m.total_j, m.total_g, m.active_j, m.lost_j, sorted(lat))


@pytest.mark.parametrize("chaos", [False, True])
def test_monitored_run_is_bit_identical(chaos):
    bare = _fleet(chaos=chaos).run(_mixed_crowd())
    res, mon = _monitored_run(chaos=chaos)
    assert _fingerprint(res) == _fingerprint(bare)
    assert mon.windows, "monitor sealed no windows"
    # window totals reconcile with the meter (same stream, same joules)
    total_j = sum(w["j"] for w in mon.windows)
    assert total_j == pytest.approx(res.fleet.meter.total_j
                                    - res.fleet.meter.lost_j)


def test_observation_guard_raises_on_stream_write():
    rec = TraceRecorder()
    rec.instant("drop", 0.0)
    with observation_guard(rec, "test tick"):
        pass                                      # clean read: no raise
    with pytest.raises(ConservationError, match="R6"):
        with observation_guard(rec, "test tick"):
            rec.instant("drop", 1.0)


def test_finalize_replays_alert_stream(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    res, mon = _monitored_run(chaos=True)
    assert mon.alerts, "chaos run should alert"
    # the finalize that ran inside _monitored_run already re-proved the
    # stream; tamper with history and the replay must fail loudly
    mon._finalized = False
    mon.alerts.append(_alert(99.0))
    with pytest.raises(ConservationError, match="determinism"):
        mon.finalize()


# -- detection ----------------------------------------------------------------

def test_chaos_pages_healthy_stays_quiet():
    res, mon = _monitored_run(chaos=True)
    pages = [a for a in mon.alerts if a["severity"] == "page"]
    assert pages, "scripted crashes must page"
    assert any(a["budget"] == "crashes" for a in pages)
    assert mon.incidents and mon.incidents[0]["severity"] == "page"
    crash_t = 0.15
    first_page = min(a["t"] for a in pages)
    assert first_page >= crash_t
    assert first_page - crash_t <= 1.0, "detection took too long"

    _, quiet = _monitored_run(chaos=False)
    assert quiet.alerts == []
    assert quiet.incidents == []
    remaining = quiet.budget_remaining()
    assert remaining["crashes"]["spent"] == 0
    assert remaining["loss"]["spent"] == 0


# -- bench scoring units ------------------------------------------------------

def test_score_detections_units():
    from benchmarks.bench_monitor import EVENTS, GRACE_S, score_detections
    alerts = [{"t": ev.t_s + 0.25, "severity": "page"} for ev in EVENTS]
    incidents = [{"start": ev.t_s, "end": ev.t_s + 0.5, "severity": "page"}
                 for ev in EVENTS]
    rows, precision = score_detections(alerts, incidents)
    assert all(r["detected"] for r in rows)
    assert all(r["ttd_s"] == pytest.approx(0.25) for r in rows)
    assert precision == 1.0
    assert {r["class"] for r in rows} == {"crash", "outage", "brownout"}
    # a page far outside every event window costs precision
    spurious = incidents + [{"start": 99.0, "end": 99.5, "severity": "page"}]
    _, precision = score_detections(alerts, spurious)
    assert precision == pytest.approx(len(incidents)
                                      / (len(incidents) + 1))
    # an undetected event is a recall miss, not an error
    rows, _ = score_detections([], [])
    assert not any(r["detected"] for r in rows)
    assert all(r["ttd_s"] is None for r in rows)
    last = max(ev.t_s + (ev.duration_s or 0.0) for ev in EVENTS)
    assert GRACE_S > 0 and last > 0


# -- dashboard ----------------------------------------------------------------

def test_dashboard_render_smoke(tmp_path):
    res, mon = _monitored_run(chaos=True)
    html_text = render_dashboard(mon, title="test ops",
                                 meta={"cell": "unit"})
    assert "<svg" in html_text
    assert "test ops" in html_text
    assert "crashes" in html_text            # budget table row
    assert "incident" in html_text.lower()
    out = tmp_path / "dash.html"
    write_dashboard(str(out), mon, title="file smoke")
    assert out.read_text().startswith("<!DOCTYPE html>")
    # an unmonitored-quiet dashboard renders too (no incidents banner)
    _, quiet = _monitored_run(chaos=False)
    assert "no incidents detected" in render_dashboard(quiet)

"""Runtime conservation sanitizer: ``REPRO_SANITIZE=1`` audits every meter.

Three layers:

  * meter-level — a :class:`SanitizedEnergyMeter` re-derives each billing
    event's deltas and the global joule/gram conservation identities, and
    detects out-of-band mutation (a mis-billed segment) between events;
  * mutation — breaking the underlying meter's arithmetic (under-billing a
    segment) raises :class:`ConservationError` whose message names the
    offending event with its arguments, which is the debuggability the
    sanitizer exists for;
  * grid — the policy x router x disagg serving grid runs green under the
    sanitizer, bit-identically to the unsanitized run.

The grid reuses the flash-crowd fixtures from ``test_admission`` so the
sanitizer sees the exact traffic the conservation contract was written
against (preemption, handoffs, autoscaling cold starts).
"""

import pytest

from repro.energy.meter import EnergyMeter
from repro.energy.sanitize import (
    ConservationError,
    SanitizedEnergyMeter,
    new_meter,
    sanitize_enabled,
)

from test_admission import (
    ROUTERS,
    _disagg_fleet,
    _disagg_runtime,
    _grid_fleet,
    _mixed_flash_crowd,
    assert_conserved_jg,
)

POLICIES_GRID = ("realtime", "dynamic_batch", "adaptive_batch")


# -- the factory ---------------------------------------------------------------


def test_new_meter_respects_env(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    assert type(new_meter()) is EnergyMeter
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()
    assert type(new_meter()) is SanitizedEnergyMeter
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanitize_enabled()


# -- meter-level auditing ------------------------------------------------------


def _meter(**kw):
    kw.setdefault("active_power_w", 100.0)
    kw.setdefault("idle_power_w", 20.0)
    return SanitizedEnergyMeter(**kw)


def test_clean_event_sequence_passes_and_matches_plain_meter():
    plain = EnergyMeter(active_power_w=100.0, idle_power_w=20.0)
    sane = _meter()
    for m in (plain, sane):
        m.record_active(0.5, rids=[1, 2], tokens=10, t_s=0.0)
        m.record_idle(0.25, t_s=0.5)
        m.record_preempt(0.01, t_s=0.75)
        m.record_xfer(0.02, 15.0, t_s=0.76)
        m.record_active_shared(1.0, {3: 1.2, 4: 1.4}, tokens=4)
    assert sane.total_j == plain.total_j
    assert sane.total_g == plain.total_g
    assert sane.per_request_j == plain.per_request_j
    assert sane.summary() == plain.summary()


def test_tamper_between_events_is_named(monkeypatch):
    m = _meter()
    m.record_active(0.5, rids=[7], t_s=0.0)
    m.active_s += 0.1               # a mis-billed segment, out of band
    with pytest.raises(ConservationError) as ei:
        m.record_idle(0.1, t_s=0.5)
    msg = str(ei.value)
    assert "active_s" in msg                      # which field drifted
    assert "record_idle(dur_s=0.1" in msg         # at which event
    assert "outside the meter API" in msg


def test_tampered_attribution_is_caught():
    m = _meter()
    m.record_active(0.5, rids=[7], t_s=0.0)
    m.per_request_j[7] *= 2.0
    with pytest.raises(ConservationError, match="sum_req_j"):
        m.record_idle(0.1, t_s=0.5)


def test_negative_duration_is_rejected():
    m = _meter()
    with pytest.raises(ConservationError, match="negative duration"):
        m.record_active(-0.5, t_s=0.0)
    # float residue from `uptime - billed` subtractions is not an error
    m.record_idle(-1e-9, t_s=0.0)


def test_unattributed_active_is_tracked_not_lost():
    m = _meter()
    m.record_active(0.5, rids=[], t_s=0.0)        # no attribution
    m.record_active(0.25, rids=[1], t_s=0.5)      # attributed
    assert m.per_request_j == {1: pytest.approx(25.0)}
    assert m.active_j == pytest.approx(75.0)      # nothing vanished


def test_merge_conserves_and_folds_plain_meters():
    agg = _meter()
    part = EnergyMeter(active_power_w=50.0, idle_power_w=5.0)
    part.record_active(1.0, rids=[1], t_s=0.0)
    part.record_idle(2.0, t_s=1.0)
    part.record_xfer(0.1, 8.0, t_s=3.0)
    pre_j, pre_g = agg.total_j, agg.total_g
    agg.merge(part, source="r0")
    assert agg.total_j == pytest.approx(pre_j + part.total_j)
    assert agg.total_g == pytest.approx(pre_g + part.total_g)
    # and the aggregate still passes its own audit on the next event
    agg.record_idle(0.1, t_s=3.1)


def test_sanitizer_summary_is_bit_identical_to_plain(monkeypatch):
    """Turning the sanitizer on must never change results, only check
    them — the whole point of an observer."""
    def drive(meter_cls):
        m = meter_cls(active_power_w=80.0, idle_power_w=10.0)
        for i in range(50):
            m.record_active(0.01 * (i % 7 + 1), rids=[i], tokens=3,
                            t_s=0.1 * i)
            m.record_idle(0.005, t_s=0.1 * i + 0.05)
        return m.summary()
    assert drive(SanitizedEnergyMeter) == drive(EnergyMeter)


# -- mutation: a mis-billed segment names its event ----------------------------


def test_underbilled_active_segment_names_event(monkeypatch):
    orig = EnergyMeter.record_active

    def underbilled(self, dur_s, rids=(), tokens=0, t_s=None, power_w=None):
        return orig(self, dur_s * 0.5, rids, tokens, t_s, power_w)

    monkeypatch.setattr(EnergyMeter, "record_active", underbilled)
    m = _meter()
    with pytest.raises(ConservationError) as ei:
        m.record_active(0.01, rids=[1], t_s=0.0)
    msg = str(ei.value)
    assert "record_active(dur_s=0.01, rids=[1]" in msg
    assert "active_s moved by" in msg


def test_misbilled_segment_in_grid_run_names_event(monkeypatch):
    """End-to-end: one broken billing site inside a full serving run is
    caught at its first event, with the event context in the error."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    orig = EnergyMeter.record_idle

    def underbilled(self, dur_s, t_s=None):
        return orig(self, dur_s * 0.5, t_s)

    monkeypatch.setattr(EnergyMeter, "record_idle", underbilled)
    fleet = _grid_fleet("round_robin", "dynamic_batch")
    with pytest.raises(ConservationError) as ei:
        fleet.run(_mixed_flash_crowd(80))
    msg = str(ei.value)
    assert "record_idle(dur_s=" in msg
    assert "idle_s moved by" in msg


# -- the serving grid under the sanitizer --------------------------------------


@pytest.mark.parametrize("router", ROUTERS)
@pytest.mark.parametrize("policy", POLICIES_GRID)
def test_grid_runs_green_under_sanitizer(monkeypatch, policy, router):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    fleet = _grid_fleet(router, policy)
    res = fleet.run(_mixed_flash_crowd(80))
    assert len(res.fleet.responses) == 80
    assert isinstance(res.fleet.meter, SanitizedEnergyMeter)
    assert_conserved_jg(res.fleet)


def test_disagg_runs_green_under_sanitizer(monkeypatch):
    from repro.workload.generators import poisson
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    wl = poisson(60, 8, 6, 1000, rate_per_s=200.0, seed=3)
    fleet = _disagg_fleet(_disagg_runtime())
    res = fleet.run({"llm": wl})
    m = res.endpoints["llm"]
    assert {r.rid for r in m.responses} == {r.rid for r in wl}
    assert m.meter.xfer_j > 0                    # the handoffs were audited
    assert isinstance(m.meter, SanitizedEnergyMeter)
    assert_conserved_jg(m)
    assert_conserved_jg(res.fleet)


def test_sanitized_run_is_bit_identical_to_plain(monkeypatch):
    """REPRO_SANITIZE must be a pure observer of the simulation."""
    def run(env):
        if env:
            monkeypatch.setenv("REPRO_SANITIZE", "1")
        else:
            monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        fleet = _grid_fleet("least_loaded", "dynamic_batch")
        m = fleet.run(_mixed_flash_crowd(80)).fleet
        return (m.meter.total_j, m.meter.total_g,
                sorted((r.rid, r.done_s) for r in m.responses))
    assert run(True) == run(False)

"""simlint: the static invariant analyzer must keep the repo clean AND
catch reintroduced violations.

Three layers of coverage:

  * unit — each rule fires on a minimal synthetic blob via ``lint_source``
    and stays quiet on the sanctioned spelling;
  * repo — the real tree lints clean with an EMPTY baseline (the CI
    acceptance bar);
  * mutation — copying the tree, reintroducing ``wall * power`` in
    ``serving/fleet.py`` or ``time.time()`` in ``serving/core.py``, and
    running the CLI must exit non-zero and name the file, line and rule.
"""

import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import lint_paths, lint_source
from repro.analysis.engine import classify

REPO = Path(__file__).resolve().parent.parent

# synthetic paths that classify() maps into each scope
SIM = "src/repro/serving/synthetic.py"
DRIVER = "benchmarks/synthetic.py"


def _rules(src, path=SIM, scope=None):
    return [(f.rule, f.line) for f in lint_source(src, path, scope=scope)]


# ---------------------------------------------------------------- unit: R1
def test_billed_time_flags_inline_wall_times_power():
    src = "def bill(wall_s, power_w):\n    return wall_s * power_w\n"
    assert ("billed-time", 2) in _rules(src)


def test_billed_time_allows_meter_module():
    src = "def bill(wall_s, power_w):\n    return wall_s * power_w\n"
    assert lint_source(src, "src/repro/energy/meter.py") == []


def test_billed_time_ignores_rates_and_composites():
    # a rate (req per second) times a power-free factor is not billing;
    # neither is a composite term that already mixes both on one side
    src = ("def ok(rate_per_s, n, energy_w_s):\n"
           "    a = rate_per_s * n\n"
           "    b = energy_w_s * n\n"
           "    return a + b\n")
    assert _rules(src) == []


def test_billed_time_applies_in_driver_scope():
    src = "e = elapsed_s * gpu_power_w\n"
    assert ("billed-time", 1) in _rules(src, path=DRIVER)


# ---------------------------------------------------------------- unit: R2
def test_wall_clock_flags_time_calls():
    src = "import time\nnow = time.time()\n"
    assert ("wall-clock", 2) in _rules(src)


def test_wall_clock_flags_perf_counter_from_import():
    src = "from time import perf_counter\nt0 = perf_counter()\n"
    assert ("wall-clock", 2) in _rules(src)


def test_wall_clock_flags_datetime_now():
    src = "import datetime\nd = datetime.datetime.now()\n"
    assert ("wall-clock", 2) in _rules(src)


def test_wall_clock_not_enforced_in_driver_scope():
    # benchmarks legitimately time themselves with the host clock
    src = "import time\nnow = time.time()\n"
    assert _rules(src, path=DRIVER) == []


def test_pragma_suppresses_same_line():
    src = "import time\nt0 = time.perf_counter()  # simlint: allow(wall-clock)\n"
    assert _rules(src) == []


def test_pragma_suppresses_preceding_line():
    src = ("import time\n"
           "# simlint: allow(wall-clock)\n"
           "t0 = time.perf_counter()\n")
    assert _rules(src) == []


def test_pragma_wrong_rule_does_not_suppress():
    src = "import time\nt0 = time.perf_counter()  # simlint: allow(id-key)\n"
    assert ("wall-clock", 2) in _rules(src)


def test_unseeded_numpy_random_flagged_jax_random_not():
    src = ("import numpy as np\n"
           "import jax\n"
           "a = np.random.rand(3)\n"
           "b = jax.random.normal(jax.random.PRNGKey(0), (3,))\n")
    found = _rules(src)
    assert ("unseeded-random", 3) in found
    assert all(line != 4 for _, line in found)


def test_zero_arg_rng_ctor_flagged_seeded_not():
    src = ("import numpy as np\n"
           "bad = np.random.default_rng()\n"
           "good = np.random.default_rng(1234)\n")
    found = _rules(src)
    assert ("unseeded-random", 2) in found
    assert all(line != 3 for _, line in found)


def test_set_iteration_flagged_sorted_not():
    src = ("for x in {3, 1, 2}:\n"
           "    pass\n"
           "for y in sorted({3, 1, 2}):\n"
           "    pass\n")
    found = _rules(src)
    assert ("set-iteration", 1) in found
    assert all(line != 3 for _, line in found)


def test_id_key_flagged():
    src = "cache = {}\ncache[id(obj)] = 1\n"
    assert ("id-key", 2) in _rules(src)


# ---------------------------------------------------------------- unit: R4
def test_clock_write_outside_core_flagged():
    src = "def f(core):\n    core.clock = 10.0\n"
    assert ("clock-causality", 2) in _rules(src, path=SIM)


def test_clock_write_inside_core_allowed():
    src = "class C:\n    def advance(self, t):\n        self.clock = t\n"
    assert lint_source(src, "src/repro/serving/core.py") == []


def test_billing_event_without_timestamp_flagged():
    src = "def f(m, d):\n    m.record_active(d)\n"
    found = _rules(src, path=SIM)
    assert ("clock-causality", 2) in found
    ok = "def g(m, d, t):\n    m.record_active(d, t_s=t)\n"
    assert _rules(ok, path=SIM) == []


# ----------------------------------------------------------------- scoping
def test_out_of_scope_paths_are_not_linted():
    assert classify("src/repro/models/transformer.py") is None
    src = "import time\nnow = time.time()\n"
    assert lint_source(src, "src/repro/models/transformer.py") == []


# -------------------------------------------------------------- repo clean
def test_repo_lints_clean_with_empty_baseline():
    paths = [str(REPO / "src" / "repro"), str(REPO / "benchmarks"),
             str(REPO / "scripts")]
    findings, scanned = lint_paths([p for p in paths if os.path.isdir(p)])
    assert scanned > 20
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------- CLI + mutation
def _run_cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd or str(REPO))


def test_cli_strict_clean_repo_exits_0():
    res = _run_cli("--strict", "src/repro", "benchmarks", "scripts")
    assert res.returncode == 0, res.stdout + res.stderr


def test_cli_missing_path_exits_2():
    res = _run_cli("--strict", "no/such/dir")
    assert res.returncode == 2


@pytest.fixture()
def mutated_tree(tmp_path):
    """A copy of src/repro with room to reintroduce violations."""
    dst = tmp_path / "repro"
    shutil.copytree(REPO / "src" / "repro", dst,
                    ignore=shutil.ignore_patterns("__pycache__"))
    return dst


def test_mutated_fleet_inline_billing_fails_strict(mutated_tree):
    fleet = mutated_tree / "serving" / "fleet.py"
    src = fleet.read_text()
    fleet.write_text(src + "\n\ndef _leak(wall_s, power_w):\n"
                           "    return wall_s * power_w\n")
    bad_line = src.count("\n") + 4
    res = _run_cli("--strict", str(mutated_tree))
    assert res.returncode == 1, res.stdout + res.stderr
    assert "billed-time" in res.stdout
    assert f"fleet.py:{bad_line}" in res.stdout


def test_mutated_core_wall_clock_fails_strict(mutated_tree):
    core = mutated_tree / "serving" / "core.py"
    src = core.read_text()
    core.write_text(src + "\n\nimport time\n\ndef _leak_now():\n"
                          "    return time.time()\n")
    bad_line = src.count("\n") + 6
    res = _run_cli("--strict", str(mutated_tree))
    assert res.returncode == 1, res.stdout + res.stderr
    assert "wall-clock" in res.stdout
    assert f"core.py:{bad_line}" in res.stdout


def test_mutation_without_strict_reports_but_exits_0(mutated_tree):
    core = mutated_tree / "serving" / "core.py"
    core.write_text(core.read_text() + "\nimport time\nx = time.time()\n")
    res = _run_cli(str(mutated_tree))
    assert res.returncode == 0
    assert "wall-clock" in res.stdout


def test_baseline_suppresses_known_finding(mutated_tree, tmp_path):
    core = mutated_tree / "serving" / "core.py"
    core.write_text(core.read_text() + "\nimport time\nx = time.time()\n")
    baseline = tmp_path / "baseline.json"
    res = _run_cli("--write-baseline", str(baseline), str(mutated_tree))
    assert res.returncode == 0
    res = _run_cli("--strict", "--baseline", str(baseline),
                   str(mutated_tree))
    assert res.returncode == 0, res.stdout + res.stderr

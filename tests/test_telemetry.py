"""Observability subsystem contract tests (PR 9).

Pins the two invariants the telemetry layer is built on, plus the export
format:

  * **observer purity** — a traced run is bit-identical in joules, grams
    and latencies to an untraced one, across policy x router, including
    disaggregated pools and chaos-injected failure scripts (tracing must
    never steer the simulation);
  * **span/meter reconciliation** — the joules AND grams the replica sinks
    attribute to spans decompose the meters' ``active + idle + preempt +
    xfer + lost`` buckets exactly, and the ``REPRO_SANITIZE=1`` auditing
    meter re-checks that equality after every billing event;
  * **Perfetto export** — the emitted Chrome ``trace_event`` JSON is
    schema-valid: integer pid/tid/ts, globally monotone ts, matched B/E
    pairs per track, matched async b/e pairs, named tracks.
"""

import json

import numpy as np
import pytest

from repro.core.engines import GenerationResult
from repro.energy.sanitize import ConservationError, SanitizedEnergyMeter
from repro.serving.admission.disagg import DisaggRuntime, DisaggSpec
from repro.serving.admission.priority import AdmissionControl
from repro.serving.chaos import (ChaosEvent, ChaosRuntime, ChaosSpec,
                                 RetryRuntime, RetrySpec)
from repro.serving.fleet import Autoscaler, EndpointSpec, ReplicaFleet
from repro.serving.scheduler import (DecodePhasePolicy, DynamicBatchPolicy,
                                     PrefillPhasePolicy, make_policy)
from repro.serving.telemetry import (TelemetrySpec, TraceRecorder,
                                     phase_breakdown, to_perfetto,
                                     validate_trace, write_trace)
from repro.workload.generators import bursty, poisson

ROUTERS = ("round_robin", "least_loaded", "greenest")
POLICIES = ("realtime", "dynamic_batch", "adaptive_batch")
BUCKETS = ("active", "idle", "preempt", "xfer", "lost")


class FakeEngine:
    """Deterministic timings, no model — telemetry mechanics only."""

    def __init__(self, prefill_s=0.01, step_s=0.005):
        self.prefill_s = prefill_s
        self.step_s = step_s
        self.cfg = type("Cfg", (), {"vocab_size": 1000})()

    def generate(self, tokens, max_new):
        B = tokens.shape[0]
        return GenerationResult(
            tokens=np.ones((B, max_new), np.int32),
            prefill_s=self.prefill_s,
            decode_s=self.step_s * (max_new - 1),
            n_steps=max_new,
        )


def _mixed_crowd(n=120):
    chat = poisson(n // 2, 8, 4, 1000, rate_per_s=300.0, seed=7,
                   priority="interactive", slo_ms=100.0)
    bulk = bursty(n // 2, 8, 6, 1000, rate_per_s=60.0, burst_n=20,
                  burst_every_s=0.5, burst_rate_per_s=800.0, seed=8,
                  rid0=10_000, priority="batch")
    return {"chat": chat, "bulk": bulk}


def _grid_fleet(router, policy, telemetry=None):
    adm = AdmissionControl(preempt=True, pause_s=0.001, resume_s=0.001)
    fleet = ReplicaFleet(router=router,
                         autoscaler=Autoscaler(window_s=0.25,
                                               cold_start_s=0.05),
                         telemetry=telemetry)
    for name in ("chat", "bulk"):
        fleet.add_endpoint(EndpointSpec(
            name=name,
            engine=FakeEngine(),
            policy_factory=lambda policy=policy: make_policy(
                policy, max_batch=8, timeout_ms=10.0),
            min_replicas=1, max_replicas=3, initial_replicas=2,
            admission=adm,
        ))
    return fleet


def _disagg_fleet(telemetry=None):
    rt = DisaggRuntime.from_spec(
        DisaggSpec(enabled=True, prefill_replicas=2, decode_replicas=2,
                   link_gbps=10.0, link_latency_ms=0.2, link_power_w=15.0,
                   kv_bytes_per_token=50_000.0), cfg=None,
        prefill_policy_factory=lambda: PrefillPhasePolicy(8, 5.0),
        decode_policy_factory=lambda: DecodePhasePolicy(8, 5.0))
    fleet = ReplicaFleet(router="round_robin", telemetry=telemetry)
    fleet.add_endpoint(EndpointSpec(
        name="llm", engine=FakeEngine(),
        policy_factory=lambda: DynamicBatchPolicy(8, 5.0),
        disagg=rt,
    ))
    return fleet


def _chaos_fleet(telemetry=None):
    fleet = ReplicaFleet(
        router="least_loaded",
        autoscaler=Autoscaler(window_s=0.25, cold_start_s=0.05),
        chaos=ChaosRuntime.from_spec(ChaosSpec(
            events=(ChaosEvent(kind="crash", t_s=1.0),
                    ChaosEvent(kind="crash", t_s=2.0)), seed=11)),
        retry=RetryRuntime.from_spec(RetrySpec(max_retries=3,
                                               backoff_s=0.02)),
        telemetry=telemetry)
    fleet.add_endpoint(EndpointSpec(
        name="chat", engine=FakeEngine(),
        policy_factory=lambda: make_policy("dynamic_batch", max_batch=4,
                                           timeout_ms=10.0),
        min_replicas=2, max_replicas=4, initial_replicas=4,
    ))
    return fleet


def _fingerprint(res):
    m = res.fleet.meter
    return (repr(m.total_j), repr(m.total_g),
            repr(sorted((r.rid, r.first_token_s, r.done_s)
                        for r in res.fleet.responses)))


def _assert_reconciled(rec, meters):
    """Span-attributed J and g decompose the meters' buckets exactly."""
    bj, bg = rec.bucket_totals()
    for k in BUCKETS:
        want_j = sum(getattr(m, f"{k}_j") for m in meters)
        want_g = sum(getattr(m, f"{k}_g") for m in meters)
        assert bj.get(k, 0.0) == pytest.approx(want_j, rel=1e-9, abs=1e-9)
        assert bg.get(k, 0.0) == pytest.approx(want_g, rel=1e-9, abs=1e-9)


# -- spec validation -----------------------------------------------------------


def test_telemetry_spec_problems():
    assert not TelemetrySpec().problems()
    assert not TelemetrySpec(enabled=True).problems()
    assert TelemetrySpec(max_events=0).problems()
    assert TelemetrySpec(enabled=True, spans=False, metrics=False).problems()
    # disabled telemetry may leave both families off (nothing records)
    assert not TelemetrySpec(spans=False, metrics=False).problems()


def test_telemetry_spec_rides_serving_spec():
    from repro.serving.api import ServingSpec, SpecError
    from repro.serving.api import EndpointSpec as ApiEndpoint
    ep = ApiEndpoint(name="m", arch="minitron-4b-smoke")
    spec = ServingSpec(endpoints=(ep,),
                       telemetry=TelemetrySpec(enabled=True, max_events=9))
    spec.validate()
    back = ServingSpec.from_json(spec.to_json())
    assert back == spec and back.telemetry.max_events == 9
    with pytest.raises(SpecError, match="telemetry.max_events"):
        ServingSpec(endpoints=(ep,),
                    telemetry=TelemetrySpec(max_events=-1)).validate()


# -- observer purity -----------------------------------------------------------


@pytest.mark.parametrize("router", ROUTERS)
@pytest.mark.parametrize("policy", POLICIES)
def test_traced_run_is_bit_identical(policy, router):
    rec = TraceRecorder()
    traced = _grid_fleet(router, policy, telemetry=rec).run(_mixed_crowd())
    plain = _grid_fleet(router, policy).run(_mixed_crowd())
    assert _fingerprint(traced) == _fingerprint(plain)
    assert rec.events and rec.sinks
    _assert_reconciled(rec, [traced.fleet.meter])


def test_traced_disagg_is_bit_identical_and_reconciles():
    rec = TraceRecorder()
    wl = {"llm": poisson(60, 8, 6, 1000, rate_per_s=200.0, seed=3)}
    traced = _disagg_fleet(telemetry=rec).run(wl)
    plain = _disagg_fleet().run(wl)
    assert _fingerprint(traced) == _fingerprint(plain)
    assert traced.fleet.meter.xfer_j > 0
    _assert_reconciled(rec, [traced.fleet.meter])
    assert any(e[0] == "inst" and e[3] == "kv_handoff" for e in rec.events)


def test_traced_chaos_is_bit_identical_and_reconciles():
    wl = {"chat": poisson(300, 8, 6, 1000, rate_per_s=80.0, seed=5)}
    rec = TraceRecorder()
    traced = _chaos_fleet(telemetry=rec).run(wl)
    plain = _chaos_fleet().run(wl)
    assert _fingerprint(traced) == _fingerprint(plain)
    assert traced.fleet.meter.lost_j > 0      # a crash really hit work
    _assert_reconciled(rec, [traced.fleet.meter])
    kinds = {e[3] for e in rec.events if e[0] == "inst"}
    assert {"crash", "crash_loss", "retry"} <= kinds


def test_sanitizer_checks_span_reconciliation(monkeypatch):
    """Under REPRO_SANITIZE=1 the auditing meter re-checks span/meter
    bucket equality after every event — and a tampered sink fails loudly."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    rec = TraceRecorder()
    res = _grid_fleet("least_loaded", "dynamic_batch",
                      telemetry=rec).run(_mixed_crowd(80))
    assert len(res.fleet.responses) == 80
    _assert_reconciled(rec, [res.fleet.meter])

    sink = rec.sink_for("chat", "chat/tampered")
    meter = SanitizedEnergyMeter(active_power_w=100.0, idle_power_w=20.0)
    meter.tracer = sink
    meter.record_active(0.5, rids=[1], tokens=4, t_s=0.0)
    sink.bucket_j["active"] += 1.0            # tamper with the span ledger
    with pytest.raises(ConservationError, match="span-attributed"):
        meter.record_idle(0.1, t_s=0.5)


def test_sanitized_traced_run_matches_plain_traced_run(monkeypatch):
    def run(env):
        if env:
            monkeypatch.setenv("REPRO_SANITIZE", "1")
        else:
            monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        rec = TraceRecorder()
        res = _grid_fleet("round_robin", "adaptive_batch",
                          telemetry=rec).run(_mixed_crowd(80))
        return _fingerprint(res)
    assert run(True) == run(False)


# -- the export ----------------------------------------------------------------


def _traced_chaos_recorder():
    wl = {"chat": poisson(300, 8, 6, 1000, rate_per_s=80.0, seed=5)}
    rec = TraceRecorder()
    res = _chaos_fleet(telemetry=rec).run(wl)
    m = res.fleet.meter
    rec.attach_request_energy(dict(m.per_request_j), dict(m.per_request_g))
    return rec, res


def test_perfetto_export_is_schema_valid():
    rec, _ = _traced_chaos_recorder()
    doc = to_perfetto(rec)
    assert validate_trace(doc) == []
    assert doc["otherData"]["clock"] == "virtual"
    # per-replica named tracks, fleet track, request async spans
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert "router" in names and any(n.startswith("chat/") for n in names)
    phs = {e.get("ph") for e in doc["traceEvents"]}
    assert {"B", "E", "b", "e", "i", "C", "M"} <= phs
    ts = [e["ts"] for e in doc["traceEvents"] if e.get("ph") != "M"]
    assert ts == sorted(ts) and all(isinstance(t, int) for t in ts)


def test_perfetto_sort_indices_pin_track_layout():
    """Every named track carries a deterministic sort index: the fleet
    process is 0, endpoints rank alphabetically, replicas rank
    alphabetically within their endpoint."""
    rec, _ = _traced_chaos_recorder()
    meta = [e for e in to_perfetto(rec)["traceEvents"] if e.get("ph") == "M"]
    pnames = {e["pid"]: e["args"]["name"] for e in meta
              if e["name"] == "process_name"}
    psort = {e["pid"]: e["args"]["sort_index"] for e in meta
             if e["name"] == "process_sort_index"}
    assert set(psort) == set(pnames)
    assert psort[0] == 0                      # the fleet pins the top
    ranked = sorted((i for p, i in psort.items() if p != 0))
    by_rank = [pnames[p] for p, i in sorted(psort.items(),
                                            key=lambda kv: kv[1]) if p != 0]
    assert ranked == list(range(1, len(ranked) + 1))
    assert by_rank == sorted(by_rank)         # endpoints alphabetical
    tnames = {(e["pid"], e["tid"]): e["args"]["name"] for e in meta
              if e["name"] == "thread_name"}
    tsort = {(e["pid"], e["tid"]): e["args"]["sort_index"] for e in meta
             if e["name"] == "thread_sort_index"}
    assert set(tsort) == set(tnames)
    by_pid = {}
    for (pid, tid), idx in tsort.items():
        by_pid.setdefault(pid, []).append((idx, tnames[(pid, tid)]))
    for pid, rows in by_pid.items():
        rows.sort()
        idxs = [i for i, _ in rows]
        assert len(set(idxs)) == len(idxs)    # unique within the process
        if pid != 0:
            assert [n for _, n in rows] == sorted(n for _, n in rows)


def test_validate_trace_demands_sort_indices():
    rec, _ = _traced_chaos_recorder()
    doc = to_perfetto(rec)
    doc["traceEvents"] = [e for e in doc["traceEvents"]
                          if not (e.get("ph") == "M"
                                  and e.get("name") == "thread_sort_index")]
    assert any("thread_sort_index" in p for p in validate_trace(doc))
    doc = to_perfetto(rec)
    doc["traceEvents"] = [e for e in doc["traceEvents"]
                          if not (e.get("ph") == "M"
                                  and e.get("name") == "process_sort_index")]
    assert any("process_sort_index" in p for p in validate_trace(doc))
    doc = to_perfetto(rec)
    for e in doc["traceEvents"]:              # collide two thread ranks
        if e.get("ph") == "M" and e.get("name") == "thread_sort_index" \
                and e["pid"] != 0:
            e["args"]["sort_index"] = 7
    assert any("duplicate thread_sort_index" in p
               for p in validate_trace(doc))


def test_validate_trace_catches_breakage():
    rec, _ = _traced_chaos_recorder()
    doc = to_perfetto(rec)
    ev = [e for e in doc["traceEvents"] if e.get("ph") == "B"]
    assert ev
    ev[0]["ph"] = "E"                        # unbalance one track's stack
    assert validate_trace(doc)
    assert validate_trace({"traceEvents": []})
    assert validate_trace({})


def test_write_trace_roundtrips_json(tmp_path):
    rec, _ = _traced_chaos_recorder()
    path = tmp_path / "trace.json"
    write_trace(str(path), rec)
    doc = json.loads(path.read_text())
    assert validate_trace(doc) == []


def test_max_events_cap_counts_drops():
    rec = TraceRecorder(max_events=50)
    _grid_fleet("round_robin", "dynamic_batch",
                telemetry=rec).run(_mixed_crowd(80))
    assert len(rec.events) == 50 and rec.dropped > 0
    doc = to_perfetto(rec)
    assert doc["otherData"]["dropped_events"] == rec.dropped
    assert validate_trace(doc) == []


# -- the phase breakdown -------------------------------------------------------


def test_phase_breakdown_decomposes_latency():
    rec = TraceRecorder()
    res = _grid_fleet("least_loaded", "dynamic_batch",
                      telemetry=rec).run(_mixed_crowd())
    pb = phase_breakdown(res.fleet.responses, rec.preempt_by_rid, {})
    assert set(pb) == {"interactive", "batch"}
    for cls, phases in pb.items():
        assert set(phases) == {"queue_wait", "prefill", "xfer", "decode",
                               "preempted"}
        for row in phases.values():
            assert row["n"] > 0 and row["p50_s"] <= row["p95_s"]
    # the phases sum back to the mean latency per class
    for cls, phases in pb.items():
        rs = [r for r in res.fleet.responses
              if (r.priority or "standard") == cls]
        mean_lat = sum(r.done_s - r.arrival_s for r in rs) / len(rs)
        mean_sum = sum(p["mean_s"] for p in phases.values())
        assert mean_sum == pytest.approx(mean_lat, rel=1e-9)


# -- pooled sweeps -------------------------------------------------------------


def _traced_cell(n):
    """Pool worker: one traced cell -> (phase table, capped-drop count).

    Module-level so the forkserver pool can pickle it by reference; the
    tight ``max_events`` cap forces drops so the drop accounting itself is
    part of the serial-vs-pooled equality.
    """
    rec = TraceRecorder(max_events=40)
    res = _grid_fleet("least_loaded", "dynamic_batch",
                      telemetry=rec).run(_mixed_crowd(n))
    pb = phase_breakdown(res.fleet.responses, rec.preempt_by_rid, {})
    return pb, rec.dropped


def test_pooled_traced_cells_match_serial():
    """Traced cells through ``benchmarks.pool.run_cells --jobs 2`` report
    bit-identical phase-breakdown tables and capped-drop counts to the
    serial (``jobs=1``) path, in the same cell order."""
    from benchmarks.pool import run_cells
    cells = [60, 80]
    serial = run_cells(_traced_cell, cells, jobs=1)
    pooled = run_cells(_traced_cell, cells, jobs=2)
    assert pooled == serial
    assert all(dropped > 0 for _, dropped in serial)
    assert [set(pb) for pb, _ in serial] == [{"interactive", "batch"}] * 2

"""Contract tests for the declarative ServingSpec / ServingSession API.

Covers the redesign's load-bearing guarantees:
  * spec serialization — ``from_json(to_json(spec)) == spec``;
  * eager validation — unknown policy/router, duplicate endpoint names,
    negative budgets, and SLO budgets tighter than the measured floor all
    raise ``SpecError`` naming the offending field path;
  * sweep expansion — ``{path: [values]}`` grids expand to validated
    variants and reject unknown paths/endpoints;
  * adapter equivalence — ``CloudService.predict`` (now a shim) produces
    the same joules and the same retirement timeline as driving the
    session directly;
  * heterogeneous fleets — ``EndpointSpec.format`` really selects the
    replica weights (int8 bulk + fp32 quality behind one router) with
    per-replica meter provenance;
  * TD1 billing — the container choice bills its energy overhead and
    cold start into the report instead of being a doc-only artifact.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.add import (
    Deployment,
    ModelFormat,
    RequestProcessing,
    ServingInfrastructure,
)
from repro.core.engines import GenerationResult
from repro.serving.api import (
    AutoscaleSpec,
    EndpointSpec,
    ServingSession,
    ServingSpec,
    SLOClass,
    SpecError,
    endpoint_from_deployment,
    sweep,
    with_override,
)
from repro.serving.cloud import CloudService
from repro.serving.request import synth_workload
from repro.serving.stepcache import StepTimeCache, shape_bucket

ARCH = "minitron-4b-smoke"


class FakeEngine:
    """Deterministic timings, no model — session mechanics only."""

    cfg = None

    def __init__(self, prefill_s=0.01, step_s=0.005):
        self.prefill_s = prefill_s
        self.step_s = step_s

    def generate(self, tokens, max_new):
        B = tokens.shape[0]
        return GenerationResult(
            tokens=np.ones((B, max_new), np.int32),
            prefill_s=self.prefill_s,
            decode_s=self.step_s * (max_new - 1),
            n_steps=max_new,
        )


def base_spec(**kw) -> ServingSpec:
    eps = kw.pop("endpoints", None) or (
        EndpointSpec(name="chat", arch=ARCH, max_batch=8,
                     slo_classes={"interactive": SLOClass(slo_ms=100.0),
                                  "batch": SLOClass(slo_ms=None)}),
        EndpointSpec(name="bulk", arch=ARCH, policy="adaptive_batch"),
    )
    return ServingSpec(endpoints=eps, **kw)


# -- serialization -------------------------------------------------------------


def test_spec_json_roundtrip():
    spec = base_spec(router="greenest", ttft_budget_s=0.2,
                     active_power_w=90.0, idle_power_w=12.0)
    spec = with_override(spec, "endpoints.bulk.format", "rsm_int8")
    spec = with_override(spec, "endpoints.chat.autoscale.max_replicas", 6)
    back = ServingSpec.from_json(spec.to_json())
    assert back == spec
    assert back.endpoint("bulk").format == "rsm_int8"
    assert back.endpoint("chat").autoscale.max_replicas == 6
    assert back.endpoint("chat").slo_classes["interactive"].slo_ms == 100.0
    # endpoints survive as a tuple (list-built specs are coerced)
    assert isinstance(back.endpoints, tuple)
    assert ServingSpec.from_json(back.to_json()) == back


def test_from_dict_unknown_field_names_path():
    """A typo in hand-edited spec JSON raises SpecError with the path, not
    a bare TypeError from __init__."""
    doc = base_spec().to_dict()
    doc["endpoints"][0]["polcy"] = "dynamic_batch"
    with pytest.raises(SpecError, match=r"endpoints\[chat\].polcy"):
        ServingSpec.from_dict(doc)
    with pytest.raises(SpecError, match="spec.rooter"):
        ServingSpec.from_dict({"endpoints": [], "rooter": "greenest"})
    with pytest.raises(SpecError, match=r"autoscale.widnow_s"):
        ServingSpec.from_dict({"endpoints": [
            {"name": "m", "arch": ARCH, "autoscale": {"widnow_s": 1.0}}]})


def test_spec_list_endpoints_coerced():
    ep = EndpointSpec(name="m", arch=ARCH)
    assert ServingSpec(endpoints=[ep]) == ServingSpec(endpoints=(ep,))


# -- validation ----------------------------------------------------------------


@pytest.mark.parametrize("mutate,field", [
    (lambda s: dataclasses.replace(s, router="zigzag"), "router"),
    (lambda s: dataclasses.replace(s, ttft_budget_s=-1.0), "ttft_budget_s"),
    (lambda s: with_override(s, "endpoints.chat.policy", "mystery"),
     "endpoints[chat].policy"),
    (lambda s: with_override(s, "endpoints.chat.format", "onnx"),
     "endpoints[chat].format"),
    (lambda s: with_override(s, "endpoints.chat.ttft_slo_ms", -5.0),
     "endpoints[chat].ttft_slo_ms"),
    (lambda s: with_override(s, "endpoints.bulk.autoscale",
                             AutoscaleSpec(min_replicas=3, max_replicas=1)),
     "endpoints[bulk].autoscale.min_replicas"),
    (lambda s: with_override(s, "endpoints.bulk.autoscale",
                             AutoscaleSpec(window_s=-0.5)),
     "endpoints[bulk].autoscale.window_s"),
    (lambda s: with_override(s, "endpoints.chat.slo_classes",
                             {"rt": SLOClass(slo_ms=-10.0)}),
     "endpoints[chat].slo_classes[rt].slo_ms"),
])
def test_validation_names_offending_field(mutate, field):
    with pytest.raises(SpecError) as e:
        mutate(base_spec()).validate()
    assert field in str(e.value)
    assert e.value.field == field


def test_duplicate_endpoint_names_rejected():
    ep = EndpointSpec(name="chat", arch=ARCH)
    with pytest.raises(SpecError, match=r"endpoints\[1\].name.*duplicate"):
        ServingSpec(endpoints=(ep, dataclasses.replace(ep))).validate()


def test_disagreeing_autoscale_windows_rejected():
    spec = base_spec()
    spec = with_override(spec, "endpoints.bulk.autoscale",
                         AutoscaleSpec(window_s=2.0))
    with pytest.raises(SpecError, match="window_s"):
        spec.validate()


def test_slo_tighter_than_measured_floor():
    """A calibrated floor above the class budget must fail with the class's
    field path before any request is simulated."""
    spec = ServingSpec(endpoints=(
        EndpointSpec(name="chat", arch=ARCH, ttft_slo_ms=5000.0,
                     slo_classes={"rt": SLOClass(slo_ms=10.0)}),))
    session = ServingSession()
    session.deploy(spec, engines={"chat": FakeEngine()})
    cache = StepTimeCache()
    cache.put(("generate", 1, shape_bucket(8), 4), (0.05, 0.015))  # 50ms floor
    session.warm("chat", cache)
    session.submit("chat", synth_workload(5, 8, 4, 100, rate_per_s=50, seed=0))
    with pytest.raises(SpecError) as e:
        session.run()
    assert e.value.field == "endpoints[chat].slo_classes[rt].slo_ms"
    # the opt-in spec-global budget is floor-checked too
    g = ServingSession()
    g.deploy(dataclasses.replace(
        spec, ttft_budget_s=0.01,
        endpoints=(dataclasses.replace(spec.endpoints[0], slo_classes={}),)),
        engines={"chat": FakeEngine()})
    g.warm("chat", cache)
    g.submit("chat", synth_workload(5, 8, 4, 100, rate_per_s=50, seed=0))
    with pytest.raises(SpecError) as e2:
        g.run()
    assert e2.value.field == "ttft_budget_s"
    # loosening the class budget makes the same session runnable
    session.deploy(with_override(spec, "endpoints.chat.slo_classes",
                                 {"rt": SLOClass(slo_ms=500.0)}),
                   engines={"chat": FakeEngine()})
    session.warm("chat", cache)
    session.submit("chat", synth_workload(5, 8, 4, 100, rate_per_s=50, seed=0))
    assert len(session.run().endpoints["chat"].metrics.responses) == 5


def test_autoscale_spec_folds_mmc_sizing():
    """AutoscaleSpec.initial_pool is the old AutoscalePolicy.replicas_for:
    M/M/c sizing unless a hint pins the pool."""
    a = AutoscaleSpec(min_replicas=1, max_replicas=4, target_utilization=0.7)
    assert a.initial_pool(rate_per_s=100.0, service_time_s=0.01) == 2
    assert a.initial_pool(rate_per_s=1000.0, service_time_s=0.01) == 4  # clamp
    assert a.initial_pool(rate_per_s=0.1, service_time_s=0.01) == 1    # floor
    pinned = dataclasses.replace(a, replicas_hint=3)
    assert pinned.initial_pool(1000.0, 0.01) == 3


# -- sweeps --------------------------------------------------------------------


def test_sweep_expands_validated_grid():
    grid = sweep(base_spec(), {
        "router": ["round_robin", "greenest"],
        "endpoints.bulk.format": ["rsm", "rsm_int8"],
    })
    assert len(grid) == 4
    combos = {(a["router"], a["endpoints.bulk.format"]) for a, _ in grid}
    assert len(combos) == 4
    for assignment, variant in grid:
        assert variant.router == assignment["router"]
        assert variant.endpoint("bulk").format == \
            assignment["endpoints.bulk.format"]
        # untouched endpoints keep their fields
        assert variant.endpoint("chat").format == "rsm"


def test_sweep_rejects_unknown_paths():
    with pytest.raises(SpecError, match="no field"):
        sweep(base_spec(), {"endpoints.chat.exotic_knob": [1]})
    with pytest.raises(SpecError, match="no endpoint named"):
        with_override(base_spec(), "endpoints.ghost.format", "rsm")
    # infeasible cells fail at grid construction, naming the field
    with pytest.raises(SpecError, match=r"endpoints\[chat\].policy"):
        sweep(base_spec(), {"endpoints.chat.policy": ["warp_drive"]})


def test_star_override_hits_every_endpoint():
    spec = with_override(base_spec(), "endpoints.*.max_seq", 64)
    assert all(ep.max_seq == 64 for ep in spec.endpoints)


# -- mapping-path overrides (the rate x SLO sweep axes) ------------------------


def test_mapping_override_star_hits_every_slo_class():
    base = base_spec()
    spec = with_override(base, "endpoints.chat.slo_classes.*.slo_ms", 80.0)
    assert all(c.slo_ms == 80.0
               for c in spec.endpoint("chat").slo_classes.values())
    # copy-on-write: the original spec's classes are untouched
    assert base.endpoint("chat").slo_classes["interactive"].slo_ms == 100.0


def test_mapping_override_named_key_leaves_siblings():
    spec = with_override(base_spec(),
                         "endpoints.chat.slo_classes.interactive.slo_ms",
                         55.0)
    classes = spec.endpoint("chat").slo_classes
    assert classes["interactive"].slo_ms == 55.0
    assert classes["batch"].slo_ms is None


def test_mapping_override_unknown_key_rejected():
    with pytest.raises(SpecError, match="no key 'premium'"):
        with_override(base_spec(),
                      "endpoints.chat.slo_classes.premium.slo_ms", 10.0)


def test_mapping_override_needs_trailing_field():
    with pytest.raises(SpecError, match="field after the key"):
        with_override(base_spec(),
                      "endpoints.chat.slo_classes.interactive", 10.0)


def test_override_cannot_descend_into_unset_field():
    # bulk declares no workload; the path must fail loudly, not invent one
    with pytest.raises(SpecError, match="unset"):
        with_override(base_spec(), "endpoints.bulk.workload.rate_per_s",
                      100.0)


def test_sweep_rate_x_slo_axes():
    from repro.workload.generators import WorkloadSpec

    base = base_spec(endpoints=(
        EndpointSpec(name="api", arch=ARCH, max_batch=8,
                     slo_classes={"interactive": SLOClass(slo_ms=100.0)},
                     workload=WorkloadSpec(kind="poisson", n=10,
                                           rate_per_s=50.0, seed=3)),
    ))
    grid = sweep(base, {
        "endpoints.*.workload.rate_per_s": [100.0, 200.0],
        "endpoints.*.slo_classes.*.slo_ms": [60.0, 120.0],
    })
    assert len(grid) == 4
    for assignment, variant in grid:
        ep = variant.endpoint("api")
        assert ep.workload.rate_per_s == \
            assignment["endpoints.*.workload.rate_per_s"]
        assert ep.slo_classes["interactive"].slo_ms == \
            assignment["endpoints.*.slo_classes.*.slo_ms"]


# -- adapter equivalence -------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_params():
    import jax

    from repro.configs import get_arch
    from repro.models import init_params

    cfg = get_arch(ARCH)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def test_cloud_predict_equals_direct_session(tmp_path, smoke_params):
    """The CloudService shim and a hand-built session must produce the same
    joules and the same retirement timeline on an identical workload."""
    cfg, params = smoke_params
    cloud = CloudService(str(tmp_path / "registry"))
    cloud.upload_model("m", 1, params, ModelFormat.RSM)
    dep = Deployment(arch=ARCH, si=ServingInfrastructure.SI4_CLOUD_SERVICE,
                     request_processing=RequestProcessing.DYNAMIC_BATCH,
                     max_batch=4, max_seq=64, min_replicas=1, max_replicas=3,
                     autoscale_window_s=0.5, cold_start_s=0.1)
    cloud.deploy("m", 1, dep, template_params=params)
    cloud.calibrate_endpoint("m", batch_sizes=[1, 2, 3, 4], prompt_len=8,
                             max_new=3)
    wl = lambda: synth_workload(60, 8, 3, cfg.vocab_size,  # noqa: E731
                                rate_per_s=200, seed=7)
    old = cloud.predict("m", wl())

    spec = ServingSpec(endpoints=(endpoint_from_deployment("m", dep),),
                       router=dep.router)
    session = ServingSession()
    session.deploy(spec, engines={"m": cloud.endpoints["m"]["engine"]})
    session.warm("m", cloud.endpoints["m"]["warm_cache"])
    session.submit("m", wl())
    new = session.run().endpoints["m"].metrics

    assert len(old.responses) == len(new.responses) == 60
    assert old.meter.total_j == pytest.approx(new.meter.total_j, rel=1e-9)
    assert old.meter.active_j == pytest.approx(new.meter.active_j, rel=1e-9)
    old_done = sorted((r.rid, round(r.done_s, 9)) for r in old.responses)
    new_done = sorted((r.rid, round(r.done_s, 9)) for r in new.responses)
    assert old_done == new_done


def test_server_handle_fixed_single_replica(smoke_params):
    """The SI3 server adapter serves through the session on exactly one
    replica — no autoscaling, all requests answered."""
    from repro.serving.server import ModelPackage, ServingServer

    cfg, params = smoke_params
    warm = StepTimeCache()
    for b in (1, 2, 3, 4):
        warm.put(("generate", b, shape_bucket(8), 3), (0.01 * b, 0.01))
    dep = Deployment(arch=ARCH, si=ServingInfrastructure.SI3_DL_SERVER,
                     request_processing=RequestProcessing.DYNAMIC_BATCH,
                     max_batch=4, max_seq=64)
    srv = ServingServer(dep)
    srv.register(ModelPackage(name="m", arch=ARCH, params=params, max_seq=64),
                 step_cache=warm)
    wl = synth_workload(30, 8, 3, cfg.vocab_size, rate_per_s=100, seed=5)
    m = srv.handle("m", wl)
    assert len(m.responses) == 30
    assert m.fleet["replicas_created"] == 1
    assert m.fleet["cold_starts"] == 0
    assert m.meter.total_j > 0


# -- heterogeneous fleets (TD2 really selects the weights) ---------------------


def test_heterogeneous_int8_fp32_fleet(tmp_path, smoke_params):
    """One router, two formats: the bulk endpoint serves QTensor (int8)
    weights, the chat endpoint full precision, and the merged meter keeps
    per-replica provenance for both."""
    import jax

    from repro.serving.formats import QTensor

    cfg, params = smoke_params
    spec = ServingSpec(endpoints=(
        EndpointSpec(name="chat", arch=ARCH, format="rsm", model="m",
                     max_seq=64, max_batch=4,
                     autoscale=AutoscaleSpec(max_replicas=2)),
        EndpointSpec(name="bulk", arch=ARCH, format="rsm_int8", model="m",
                     max_seq=64, max_batch=4,
                     autoscale=AutoscaleSpec(max_replicas=2)),
    ), router="least_loaded")
    session = ServingSession(registry_root=str(tmp_path / "reg"))
    session.deploy(spec, params={"m": params})

    def has_qtensor(tree):
        return any(isinstance(l, QTensor) for l in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QTensor)))

    assert has_qtensor(session.engine("bulk").params)
    assert not has_qtensor(session.engine("chat").params)
    assert session.engine("bulk") is not session.engine("chat")

    for name in ("chat", "bulk"):
        session.calibrate(name, batch_sizes=[1, 2, 4], prompt_len=8,
                          max_new=3)
    report = session.serve({
        "chat": synth_workload(40, 8, 3, cfg.vocab_size, rate_per_s=150,
                               seed=1),
        "bulk": synth_workload(40, 8, 3, cfg.vocab_size, rate_per_s=150,
                               seed=2, rid0=10_000),
    })
    assert report.fleet.n_requests == 80
    # per-replica meter provenance spans BOTH formats' replica pools
    sources = set(report.fleet.metrics.meter.by_source)
    assert any(s.startswith("chat/") for s in sources)
    assert any(s.startswith("bulk/") for s in sources)
    by_src = sum(d["active_j"] + d["idle_j"]
                 for d in report.fleet.metrics.meter.by_source.values())
    assert by_src == pytest.approx(report.fleet.j_measured, rel=1e-6)
    # each endpoint's report decomposes into only its own replicas
    assert set(report.endpoints["bulk"].j_by_replica) == \
        {s for s in sources if s.startswith("bulk/")}
    assert report.endpoints["bulk"].decisions["format"] == "rsm_int8"
    assert report.endpoints["chat"].decisions["format"] == "rsm"


def test_engine_memo_shared_across_deploys(tmp_path, smoke_params):
    """Sweeping a grid must not rebuild engines for repeated formats — but
    re-deploying the same model name with DIFFERENT weights must rebuild
    (the memo keys on params identity, never serving stale weights)."""
    import jax

    from repro.models import init_params

    cfg, params = smoke_params
    session = ServingSession(registry_root=str(tmp_path / "reg"))
    spec = ServingSpec(endpoints=(
        EndpointSpec(name="m", arch=ARCH, format="rsm", max_seq=64),))
    session.deploy(spec, params={"m": params})
    e1 = session.engine("m")
    session.deploy(with_override(spec, "router", "greenest"),
                   params={"m": params})
    assert session.engine("m") is e1
    other = init_params(cfg, jax.random.PRNGKey(1))
    session.deploy(spec, params={"m": other})
    e2 = session.engine("m")
    assert e2 is not e1
    a = jax.tree.leaves(e1.params)[0]
    b = jax.tree.leaves(e2.params)[0]
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_calibrate_skips_measured_shapes():
    """Two endpoints sharing one engine (or repeated sweep cells) pay for
    exactly one calibration — already-measured shapes are not re-run."""

    class CountingEngine(FakeEngine):
        calls = 0

        def generate(self, tokens, max_new):
            CountingEngine.calls += 1
            return super().generate(tokens, max_new)

    engine = CountingEngine()
    spec = ServingSpec(endpoints=(
        EndpointSpec(name="a", arch=ARCH),
        EndpointSpec(name="b", arch=ARCH),
    ))
    session = ServingSession()
    session.deploy(spec, engines={"a": engine, "b": engine})
    session.calibrate("a", batch_sizes=[1, 2], prompt_len=8, max_new=4)
    after_first = CountingEngine.calls
    assert after_first > 0
    session.calibrate("b", batch_sizes=[1, 2], prompt_len=8, max_new=4)
    assert CountingEngine.calls == after_first


def test_floor_prefers_measured_batch_one():
    """The TTFT floor uses the real batch-1 prefill when measured; the
    linear scale-down of a batched prefill is only the no-b=1 fallback
    (a lower bound that never rejects a feasible budget)."""
    sb = shape_bucket(8)
    cache = StepTimeCache()
    cache.put(("generate", 8, sb, 4), (0.08, 0.02))   # sublinear: 0.08 at b=8
    assert cache.floor_ttft_s() == pytest.approx(0.01)  # fallback: 0.08/8
    cache.put(("generate", 1, sb, 4), (0.05, 0.01))   # true b=1 prefill
    assert cache.floor_ttft_s() == pytest.approx(0.05)


# -- TD1 billing ---------------------------------------------------------------


def test_container_choice_bills_energy_and_cold_start():
    wl = lambda: synth_workload(50, 8, 4, 100, rate_per_s=100,  # noqa: E731
                                seed=3)

    def run(container):
        spec = ServingSpec(endpoints=(
            EndpointSpec(name="m", arch=ARCH, container=container,
                         autoscale=AutoscaleSpec(max_replicas=2)),))
        session = ServingSession()
        session.deploy(spec, engines={"m": FakeEngine()})
        session.submit("m", wl())
        return session.run()

    bare = run("none")
    boxed = run("docker")
    assert bare.endpoints["m"].j_container_overhead == 0.0
    assert boxed.endpoints["m"].j_container_overhead > 0.0
    # docker bills the calibrated multiplier on measured joules
    assert boxed.endpoints["m"].j_billed == pytest.approx(
        boxed.endpoints["m"].j_measured * 1.05)
    assert boxed.fleet.j_billed > boxed.fleet.j_measured
    assert boxed.fleet.j_per_token > 0
    # and the fleet pays the container's startup on every scale-up
    session = ServingSession()
    spec = ServingSpec(endpoints=(
        EndpointSpec(name="m", arch=ARCH, container="docker"),))
    session.deploy(spec, engines={"m": FakeEngine()})
    fe = session._fleet_endpoint(spec.endpoints[0], wl())
    assert fe.cold_start_s == pytest.approx(0.25 + 1.8)


def test_frozen_endpoint_keeps_pool_in_mixed_fleet():
    """autoscale.enabled=False pins that endpoint's pool even when it shares
    the timeline (and the fleet autoscaler) with a scaled endpoint."""
    spec = ServingSpec(endpoints=(
        EndpointSpec(name="scaled", arch=ARCH,
                     autoscale=AutoscaleSpec(min_replicas=1, max_replicas=4,
                                             replicas_hint=1, window_s=0.25,
                                             cold_start_s=0.05)),
        EndpointSpec(name="frozen", arch=ARCH,
                     autoscale=AutoscaleSpec(enabled=False, replicas_hint=2,
                                             min_replicas=1, max_replicas=4,
                                             window_s=0.25,
                                             cold_start_s=0.05)),
    ), router="least_loaded")
    session = ServingSession()
    session.deploy(spec, engines={"scaled": FakeEngine(),
                                  "frozen": FakeEngine()})
    report = session.serve({
        "scaled": synth_workload(400, 8, 4, 100, rate_per_s=600, seed=6),
        "frozen": synth_workload(400, 8, 4, 100, rate_per_s=600, seed=7,
                                 rid0=10_000),
    })
    frozen = report.endpoints["frozen"].metrics.fleet
    assert frozen["replicas_created"] == 2
    assert frozen["scale_events"] == []
    # the scaled neighbour really was autoscaled on the same timeline
    assert report.endpoints["scaled"].metrics.fleet["scale_events"]


def test_global_ttft_budget_reaches_the_policy():
    """With no endpoint budget, the spec-global ttft_budget_s must steer the
    scheduling policy's batch sizing, not only the router."""
    spec = ServingSpec(
        endpoints=(EndpointSpec(name="m", arch=ARCH, policy="adaptive_batch",
                                ttft_slo_ms=None),),
        ttft_budget_s=0.05,
    ).validate()
    session = ServingSession()
    session.deploy(spec, engines={"m": FakeEngine()})
    fe = session._fleet_endpoint(spec.endpoints[0], [])
    assert fe.policy_factory().ttft_slo_s == pytest.approx(0.05)
    assert fe.ttft_slo_s == pytest.approx(0.05)


def test_submit_slo_class_does_not_mutate_caller_requests():
    spec = ServingSpec(endpoints=(
        EndpointSpec(name="m", arch=ARCH,
                     slo_classes={"rt": SLOClass(slo_ms=25.0)}),))
    session = ServingSession()
    session.deploy(spec, engines={"m": FakeEngine()})
    wl = synth_workload(5, 8, 4, 100, rate_per_s=50, seed=8)
    session.submit("m", wl, slo_class="rt")
    assert all(r.slo_ms is None for r in wl)      # caller's objects untouched
    assert all(r.slo_ms == 25.0 for r in session._workloads["m"])


def test_report_serializes_without_metrics(smoke_params):
    spec = ServingSpec(endpoints=(EndpointSpec(name="m", arch=ARCH),))
    session = ServingSession()
    session.deploy(spec, engines={"m": FakeEngine()})
    session.submit("m", synth_workload(10, 8, 4, 100, rate_per_s=50, seed=4))
    report = session.run()
    doc = report.to_dict()
    assert "metrics" not in doc["fleet"]
    assert doc["spec"]["router"] == "round_robin"
    assert ServingSpec.from_dict(doc["spec"]) == spec
    import json

    json.loads(report.to_json())   # fully JSON-serializable
